"""Telemetry subsystem (repro/obs/; DESIGN §3.15).

Covered here: (1) the unified trace schema — local and dist ``run``
emit the same canonical keys, with the pre-§3.15 names kept as
deprecated aliases; (2) batched host draining — rows are identical for
any ``trace_every`` and the number of host transfers shrinks to
``ceil(steps / trace_every)``; (3) the zero-overhead off-switch — an
engine built with telemetry enabled has a byte-identical step jaxpr to
one built without (collection never adds an op to the jitted step);
(4) snapshot-aligned aggregation — the naive live reduction over a
4-machine mesh mixes pre/post-cut rows while the marker-anchored
aggregate equals a single-machine oracle restored from the same cut,
bit-exactly; (5) Chrome-trace/JSONL export structure.
"""
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps.pagerank import PageRankProgram, make_pagerank_graph
from repro.core import Engine
from repro.core.snapshot import restore_engine_state
from repro.dist.engine import DistributedEngine
from repro.dist.locking import DistributedLockingEngine
from repro.graphs.generators import connected_power_law_graph
from repro.obs import (LEGACY_ALIASES, METRICS_SCHEMA, MetricsFrame,
                       ObsConfig, ObsSession, Supervisor, aligned_aggregate,
                       chrome_trace, live_aggregate, mixing_report,
                       write_chrome_trace, write_events_jsonl)

needs_mesh = pytest.mark.skipif(
    jax.device_count() < 4, reason="needs 4 forced host devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")


def _case(n=80, seed=3, tol=1e-9):
    g = make_pagerank_graph(connected_power_law_graph(n, seed=seed))
    return g, PageRankProgram(0.15, n), tol


def _dist(cpu_mesh, tol=1e-9, **kw):
    g, prog, _ = _case(tol=tol)
    eng = DistributedEngine(prog, g, cpu_mesh, tolerance=tol, method="bfs",
                            **kw)
    return eng, eng.init()


# ---------------------------------------------------------------------------
# satellite: one schema across local / dist / snapshot driver
# ---------------------------------------------------------------------------

CANONICAL = set(METRICS_SCHEMA) - {"beats"}


class TestUnifiedSchema:
    def test_local_rows_canonical_with_aliases(self):
        g, prog, tol = _case(n=40, tol=1e-6)
        eng = Engine(prog, g, tolerance=tol)
        _, trace = eng.run(eng.init(g), max_steps=30,
                           trace_fn=lambda s: {"custom": 1.0})
        assert trace, "local run with trace_fn must emit rows"
        row = trace[0]
        assert CANONICAL <= set(row)
        # deprecated aliases mirror the canonical values (one release)
        for canon, old in LEGACY_ALIASES.items():
            assert row[old] == row[canon]
        assert row["custom"] == 1.0
        # local engines ship nothing: traffic fields structurally zero
        assert row["traffic_rows_v"] == row["traffic_bytes_v"] == 0
        # rows are plain python scalars (drained, not device arrays)
        assert isinstance(row["updates"], int)
        assert isinstance(row["residual_max"], float)

    @needs_mesh
    def test_dist_rows_canonical_with_aliases(self, cpu_mesh):
        eng, state = _dist(cpu_mesh, tol=1e-6)
        _, trace = eng.run(state, max_steps=30)
        row = trace[0]
        assert CANONICAL <= set(row)
        for canon, old in LEGACY_ALIASES.items():
            assert row[old] == row[canon]
        last = trace[-1]
        assert last["traffic_rows_v"] > 0
        # default f32 wire: bytes are rows x a fixed per-row payload size
        assert last["traffic_bytes_v"] % last["traffic_rows_v"] == 0
        assert last["traffic_bytes_v"] >= 4 * last["traffic_rows_v"]

    def test_frames_roundtrip(self):
        g, prog, tol = _case(n=40, tol=1e-6)
        eng = Engine(prog, g, tolerance=tol)
        _, trace = eng.run(eng.init(g), max_steps=10,
                           trace_fn=lambda s: {"custom": 2.5})
        f = MetricsFrame.from_row(trace[0])
        assert f.updates == trace[0]["updates"]
        assert f.extra["custom"] == 2.5
        back = f.to_row()
        assert back["updates"] == trace[0]["updates"]
        assert back["total_updates"] == trace[0]["updates"]  # alias


# ---------------------------------------------------------------------------
# satellite: batched host draining (trace_every)
# ---------------------------------------------------------------------------

class TestTraceEvery:
    def test_rows_identical_and_transfers_batched(self):
        g, prog, tol = _case(n=40, tol=1e-6)
        runs = {}
        for every in (1, 4):
            eng = Engine(prog, g, tolerance=tol,
                         obs=ObsConfig(enabled=True, trace_every=every))
            ses = ObsSession(ObsConfig(enabled=True))
            state, trace = eng.run(eng.init(g), max_steps=30, session=ses)
            runs[every] = (trace, ses.drains)
        t1, d1 = runs[1]
        t4, d4 = runs[4]
        assert t1 == t4, "batching must not change row values"
        steps = len(t1)
        assert steps > 4
        assert d1 == steps
        assert d4 == math.ceil(steps / 4)

    @needs_mesh
    def test_dist_rows_identical_across_batching(self, cpu_mesh):
        eng, state = _dist(cpu_mesh, tol=1e-6)
        _, t1 = eng.run(state, max_steps=12, trace_every=1)
        eng2, state2 = _dist(cpu_mesh, tol=1e-6)
        _, t5 = eng2.run(state2, max_steps=12, trace_every=5)
        assert t1 == t5


# ---------------------------------------------------------------------------
# zero-overhead off-switch: obs never touches the jitted step
# ---------------------------------------------------------------------------

class TestZeroOverhead:
    def test_local_step_jaxpr_identical(self):
        g, prog, tol = _case(n=40, tol=1e-6)
        off = Engine(prog, g, tolerance=tol)
        on = Engine(prog, g, tolerance=tol,
                    obs=ObsConfig(enabled=True, trace_every=8,
                                  timeline=True,
                                  residual_quantiles=(0.5, 0.9)))
        joff = jax.make_jaxpr(lambda s: off._step(s))(off.init(g))
        jon = jax.make_jaxpr(lambda s: on._step(s))(on.init(g))
        assert str(joff) == str(jon)

    @needs_mesh
    @pytest.mark.parametrize("engine_cls", [DistributedEngine,
                                            DistributedLockingEngine],
                             ids=["sweep", "locking"])
    def test_dist_step_jaxpr_identical(self, cpu_mesh, engine_cls):
        g, prog, tol = _case(tol=1e-6)
        off = engine_cls(prog, g, cpu_mesh, tolerance=tol, method="bfs")
        on = engine_cls(prog, g, cpu_mesh, tolerance=tol, method="bfs",
                        obs=ObsConfig(enabled=True, timeline=True,
                                      residual_quantiles=(0.5,)))
        joff = jax.make_jaxpr(off._make_step())(off.init(), off._tables)
        jon = jax.make_jaxpr(on._make_step())(on.init(), on._tables)
        assert str(joff) == str(jon)


# ---------------------------------------------------------------------------
# snapshot-aligned aggregation (tentpole layer 1, aligned mode)
# ---------------------------------------------------------------------------

@needs_mesh
class TestAlignedAggregate:
    def test_marker_anchored_matches_oracle_naive_mixes(self, cpu_mesh):
        # moderate tolerance so the mesh is *partially* converged when the
        # wave starts: converged vertices stop executing (their live rows
        # stay at the cut value) while active ones keep updating during
        # the multi-step wave (their live rows advance past it) — the
        # pre/post mixture a naive per-step sum cannot see
        g, prog, tol = _case(n=80, tol=1e-4)
        eng = DistributedEngine(prog, g, cpu_mesh, tolerance=tol,
                                method="bfs")
        state = eng.init()
        n = g.structure.n_vertices
        for _ in range(200):
            state = eng.step(state)
            active = int((np.asarray(jax.device_get(state.prio))
                          > tol).sum())
            if active < n // 2:
                break
        assert 0 < active < n, "need a partially-converged mesh"
        state = eng.start_snapshot(state, (0,))
        while not eng.snapshot_complete(state):
            state = eng.step(state)
        assert eng.snapshot_violations(state) == 0

        mix = mixing_report(eng, state, field="rank")
        assert mix["rows_post_cut"] > 0, \
            "live rows must have advanced past the cut"
        assert mix["rows_pre_cut"] > 0, \
            "some rows must still be at their cut values"

        naive = live_aggregate(eng, state, field="rank")
        aligned = aligned_aggregate(eng, state, field="rank")
        assert naive != aligned["value"], \
            "the naive per-step sum mixes pre/post-cut rows"

        # single-machine oracle: restore the same cut into a local engine
        # and reduce there — bit-exact agreement, not approximate
        local = Engine(prog, g, tolerance=tol)
        restored = restore_engine_state(local, g, eng.assemble_snapshot(state))
        oracle = float(np.sum(np.asarray(
            restored.graph.vertex_data["rank"], np.float64)))
        assert aligned["value"] == oracle
        anchor = aligned["anchor"]
        assert anchor["save_step_max"] >= anchor["save_step_min"] >= 0

    def test_aligned_requires_completed_cut(self, cpu_mesh):
        eng, state = _dist(cpu_mesh)
        with pytest.raises(ValueError, match="no snapshot"):
            aligned_aggregate(eng, state, field="rank")
        state = eng.start_snapshot(state, (0,))
        state = eng.step(state)
        if not eng.snapshot_complete(state):
            with pytest.raises(ValueError, match="in flight"):
                aligned_aggregate(eng, state, field="rank")


# ---------------------------------------------------------------------------
# timeline + export
# ---------------------------------------------------------------------------

class TestTimelineExport:
    @needs_mesh
    def test_chrome_trace_and_jsonl(self, cpu_mesh, tmp_path):
        ses = ObsSession(ObsConfig(enabled=True, timeline=True))
        eng, state = _dist(cpu_mesh, tol=1e-6,
                           obs=ObsConfig(enabled=True, timeline=True))
        eng.run(state, max_steps=5, session=ses)
        ses.event("unit_test_marker", detail=42)

        doc = chrome_trace(ses.timeline, metadata={"case": "pagerank"})
        steps = [e for e in doc["traceEvents"]
                 if e.get("ph") == "X" and e["name"].startswith("step")]
        assert len(steps) == 5
        assert all(e["dur"] >= 0 and e["ts"] >= 0 for e in steps)
        phases = [e for e in doc["traceEvents"] if e.get("cat") == "phase"]
        assert phases and all(e["args"]["logical"] for e in phases)
        names = [e for e in doc["traceEvents"] if e.get("ph") == "M"]
        assert names, "thread_name metadata labels the tracks"

        p = tmp_path / "trace.json"
        write_chrome_trace(str(p), ses.timeline)
        assert json.loads(p.read_text())["traceEvents"]

        q = tmp_path / "events.jsonl"
        write_events_jsonl(str(q), ses.events)
        lines = [json.loads(ln) for ln in q.read_text().splitlines()]
        assert any(ev["kind"] == "unit_test_marker" for ev in lines)

    def test_session_rows_flow_from_local_run(self):
        g, prog, tol = _case(n=40, tol=1e-6)
        ses = ObsSession(ObsConfig(enabled=True, timeline=True))
        eng = Engine(prog, g, tolerance=tol, obs=ObsConfig(enabled=True))
        _, trace = eng.run(eng.init(g), max_steps=20, session=ses)
        assert ses.rows == trace
        assert len(ses.frames()) == len(trace)
        assert any(e["ph"] == "X" for e in ses.timeline.events)
