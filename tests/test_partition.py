"""Two-phase atom partitioning tests (paper Sec. 4.1): journal round-trip,
elastic re-balance, ghost correctness."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.apps.pagerank import make_pagerank_graph
from repro.core.partition import (AtomIndex, build_atoms, cut_edges,
                                  load_cluster, load_machine, overpartition,
                                  place_atoms)
from repro.graphs.generators import power_law_graph


@pytest.fixture(scope="module")
def graph():
    struct = power_law_graph(200, avg_degree=8, seed=7)
    return make_pagerank_graph(struct)


def _index(graph, tmp, k_atoms=16, method="hash"):
    atom_of = overpartition(graph.structure, k_atoms, method=method)
    return build_atoms(graph, atom_of, tmp), atom_of


class TestAtoms:
    def test_every_vertex_and_edge_in_exactly_one_atom(self, graph):
        with tempfile.TemporaryDirectory() as d:
            index, atom_of = _index(graph, d)
            nv = ne = 0
            seen_v, seen_e = set(), set()
            for f in index.files:
                z = np.load(f)
                nv += z["own_vertices"].size
                ne += z["edge_ids"].size
                for v in z["own_vertices"]:
                    assert v not in seen_v
                    seen_v.add(int(v))
                for e in z["edge_ids"]:
                    assert e not in seen_e
                    seen_e.add(int(e))
            assert nv == graph.n_vertices
            assert ne == graph.n_edges

    def test_journal_replay_reconstructs_data(self, graph):
        """Loading on ANY machine count reproduces vertex/edge data."""
        with tempfile.TemporaryDirectory() as d:
            index, atom_of = _index(graph, d)
            for n_machines in (2, 3, 5):
                locals_ = load_cluster(index, n_machines)
                rank = np.asarray(graph.vertex_data["rank"])
                w = np.asarray(graph.edge_data["w"])
                got_v = np.zeros_like(rank)
                got_e = np.zeros_like(w)
                for lg in locals_:
                    got_v[lg.own_global] = lg.vdata[0][:lg.n_own]
                    got_e[lg.edge_ids] = lg.edata[0]
                np.testing.assert_allclose(got_v, rank)
                np.testing.assert_allclose(got_e, w)

    def test_ghosts_cover_remote_reads(self, graph):
        """Every edge source a machine reads is either owned or a ghost
        whose cached data matches the true value (cache coherence)."""
        with tempfile.TemporaryDirectory() as d:
            index, _ = _index(graph, d)
            for lg in load_cluster(index, 4):
                rank = np.asarray(graph.vertex_data["rank"])
                n_local = lg.n_own + lg.n_ghost
                assert lg.edge_src_local.max(initial=0) < n_local
                assert lg.edge_dst_local.max(initial=0) < lg.n_own
                # ghost rows carry the true remote values
                np.testing.assert_allclose(
                    lg.vdata[0][lg.n_own:], rank[lg.ghost_global])

    def test_elastic_rebalance_without_repartition(self, graph):
        """The same atom set serves different cluster sizes with balanced
        load (paper: the point of two-phase partitioning)."""
        with tempfile.TemporaryDirectory() as d:
            index, _ = _index(graph, d, k_atoms=32)
            w = index.atom_nv + index.atom_ne
            for n_machines in (2, 4, 8):
                placement = place_atoms(index, n_machines)
                loads = np.bincount(placement, weights=w,
                                    minlength=n_machines)
                assert loads.max() <= 2.2 * loads.mean()

    def test_index_save_load_roundtrip(self, graph):
        with tempfile.TemporaryDirectory() as d:
            index, _ = _index(graph, d)
            index2 = AtomIndex.load(os.path.join(d, "atom_index.json"))
            assert index2.k_atoms == index.k_atoms
            np.testing.assert_array_equal(index2.atom_nv, index.atom_nv)
            assert cut_edges(index, place_atoms(index, 4)) == \
                cut_edges(index2, place_atoms(index2, 4))

    def test_bfs_partition_cuts_fewer_grid_edges_than_hash(self):
        """Locality-aware over-partitioning helps structured graphs
        (paper: CoSeg frame-block partition vs random)."""
        from repro.graphs.generators import grid3d_graph
        struct = grid3d_graph(6, 6, 6, connectivity=6)
        g = make_pagerank_graph(struct)
        with tempfile.TemporaryDirectory() as d1, \
                tempfile.TemporaryDirectory() as d2:
            hash_of = overpartition(struct, 16, method="hash")
            bfs_of = overpartition(struct, 16, method="bfs")
            ih = build_atoms(g, hash_of, d1)
            ib = build_atoms(g, bfs_of, d2)
            ch = cut_edges(ih, place_atoms(ih, 4))
            cb = cut_edges(ib, place_atoms(ib, 4))
            assert cb < ch


@settings(max_examples=8, deadline=None)
@given(n=st.integers(20, 120), k=st.integers(2, 24),
       seed=st.integers(0, 10**6))
def test_overpartition_assigns_every_vertex(n, k, seed):
    struct = power_law_graph(n, avg_degree=4, seed=seed)
    for method in ("hash", "bfs"):
        atom_of = overpartition(struct, k, method=method, seed=seed)
        assert atom_of.shape == (n,)
        assert atom_of.min() >= 0 and atom_of.max() < k
