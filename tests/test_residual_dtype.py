"""The f32 residual floor and its f64 opt-out (core/engine_base.py).

Scheduler residuals default to float32, so a tolerance much below ~1e-6
is unreachable: the priority array quantizes before the math does.
``residual_dtype=jnp.float64`` (with x64 enabled) lets LBP chase
tolerances the paper's convergence plots assume — this file pins the
opt-in end to end: the engine converges at tol=1e-8 and the priority
array really carries doubles.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps.lbp import LoopyBPProgram, make_mrf_graph
from repro.core import ChromaticEngine
from repro.graphs.generators import power_law_graph


@pytest.fixture
def x64():
    jax.config.update("jax_enable_x64", True)
    try:
        yield
    finally:
        jax.config.update("jax_enable_x64", False)


def test_default_residuals_are_f32():
    st_ = power_law_graph(40, avg_degree=4, seed=0)
    g = make_mrf_graph(st_, 3, seed=0)
    eng = ChromaticEngine(LoopyBPProgram(3, smoothing=0.7), g,
                          tolerance=1e-3)
    state = eng.init(g)
    assert state.prio.dtype == jnp.float32
    state = eng.step(state)
    assert state.prio.dtype == jnp.float32


def test_lbp_converges_at_1e8_with_f64_residuals(x64):
    st_ = power_law_graph(60, avg_degree=4, seed=1)
    g = make_mrf_graph(st_, 3, seed=1, dtype=jnp.float64)
    eng = ChromaticEngine(LoopyBPProgram(3, smoothing=0.7), g,
                          tolerance=1e-8, residual_dtype=jnp.float64)
    state = eng.init(g)
    assert state.prio.dtype == jnp.float64
    state, _ = eng.run(state, max_steps=400)
    assert bool(eng.scheduler.done(state.sched, state.prio)), (
        "LBP failed to drain the scheduler at tol=1e-8 "
        f"(max residual {float(state.prio.max()):.3e})")
    assert float(state.prio.max()) <= 1e-8
    # the log-beliefs are normalized distributions, not garbage
    b = np.asarray(state.graph.vertex_data["belief"])
    np.testing.assert_allclose(np.exp(b).sum(axis=1), 1.0, atol=1e-9)
