"""Scheduler subsystem tests (core/scheduler.py, DESIGN.md §3.8).

Covers the array-native Scheduler API: top-k pipeline selection, lock
arbitration safety under all three consistency models (the hypothesis
property the paper's locking engine guarantees: a parallel step only
executes an independent set under the model's exclusion radius), progress
(the minimum-rank selected vertex always wins — the FULL-consistency
regression: the old self-including two-hop min livelocked every
non-isolated vertex), FIFO ordering, and per-machine multi-queue selection.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.apps.pagerank import (PageRankProgram, exact_pagerank,
                                 make_pagerank_graph)
from repro.core import (Consistency, DynamicEngine, Engine, FifoScheduler,
                        MultiQueueScheduler, PriorityScheduler,
                        SweepScheduler)
from repro.core.graph import GraphStructure
from repro.core.scheduler import (exclusion_min, marker_wave, neighbor_min,
                                  pipeline_ranks, pipeline_select)
from repro.graphs.generators import power_law_graph

TOL = 1e-3


def random_graph(n, avg_deg, seed):
    st_ = power_law_graph(n, avg_degree=avg_deg, seed=seed)
    if st_.n_edges == 0:
        st_, _ = GraphStructure.undirected([0], [1], n)
    return st_


def program_with(model, n):
    class P(PageRankProgram):
        consistency = model
    return P(0.15, n)


def conflict_matrix(st_, radius):
    """Dense distance-≤radius conflict matrix (diagonal cleared)."""
    n = st_.n_vertices
    a = np.zeros((n, n), bool)
    a[st_.senders, st_.receivers] = True
    a |= a.T
    d = a.copy() if radius >= 1 else np.zeros((n, n), bool)
    if radius >= 2:
        d |= (a.astype(np.int32) @ a.astype(np.int32)) > 0
    np.fill_diagonal(d, False)
    return d


def random_prio(n, seed):
    rng = np.random.default_rng(seed)
    prio = rng.uniform(0, 1, n).astype(np.float32)
    prio[rng.uniform(0, 1, n) < 0.3] = 0.0  # some unscheduled
    return prio


# ---------------------------------------------------------------------------
# arbitration safety + progress (the satellite property test)
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(n=st.integers(10, 60), seed=st.integers(0, 10**6),
       pipeline=st.integers(1, 32),
       model=st.sampled_from([Consistency.VERTEX, Consistency.EDGE,
                              Consistency.FULL]))
def test_priority_scheduler_winners_respect_exclusion(n, seed, pipeline,
                                                      model):
    st_ = random_graph(n, 4, seed)
    prog = program_with(model, st_.n_vertices)
    sched = PriorityScheduler(prog, st_, TOL, pipeline)
    prio = random_prio(st_.n_vertices, seed)
    win = np.asarray(sched.select((), jnp.asarray(prio))[0])

    # winners are scheduled top-k members
    assert not win[prio <= TOL].any()
    # no two winners within the model's exclusion radius
    d = conflict_matrix(st_, model.exclusion_radius)
    ids = np.nonzero(win)[0]
    assert not d[np.ix_(ids, ids)].any(), \
        f"winners within radius {model.exclusion_radius} co-executed"
    # progress: something scheduled => something wins (the old FULL
    # arbitration livelocked here by counting v's own rank over v→u→v)
    if (prio > TOL).any():
        assert win.any(), "arbitration made no progress"


@settings(max_examples=8, deadline=None)
@given(n=st.integers(10, 60), seed=st.integers(0, 10**6),
       machines=st.integers(1, 5),
       model=st.sampled_from([Consistency.VERTEX, Consistency.EDGE,
                              Consistency.FULL]))
def test_multi_queue_winners_respect_exclusion(n, seed, machines, model):
    st_ = random_graph(n, 4, seed)
    rng = np.random.default_rng(seed + 1)
    machine_of = rng.integers(0, machines, st_.n_vertices)
    prog = program_with(model, st_.n_vertices)
    sched = MultiQueueScheduler(prog, st_, TOL, machine_of,
                                pipeline_length=4)
    prio = random_prio(st_.n_vertices, seed)
    win = np.asarray(sched.select((), jnp.asarray(prio))[0])

    assert not win[prio <= TOL].any()
    d = conflict_matrix(st_, model.exclusion_radius)
    ids = np.nonzero(win)[0]
    assert not d[np.ix_(ids, ids)].any()
    if (prio > TOL).any():
        assert win.any()


@settings(max_examples=8, deadline=None)
@given(n=st.integers(8, 50), seed=st.integers(0, 10**6))
def test_multi_queue_selects_per_machine_topk(n, seed):
    """Before arbitration, each queue independently pops its top-p — the
    paper's per-machine schedulers."""
    st_ = random_graph(n, 4, seed)
    rng = np.random.default_rng(seed + 2)
    machine_of = rng.integers(0, 3, st_.n_vertices)
    prog = program_with(Consistency.VERTEX, st_.n_vertices)  # no exclusion
    p = 3
    sched = MultiQueueScheduler(prog, st_, TOL, machine_of, pipeline_length=p)
    prio = random_prio(st_.n_vertices, seed)
    win = np.asarray(sched.select((), jnp.asarray(prio))[0])
    for m in range(3):
        mine = np.nonzero((machine_of == m) & (prio > TOL))[0]
        expect = set(mine[np.argsort(-prio[mine], kind="stable")][:p])
        assert set(np.nonzero(win & (machine_of == m))[0]) == expect


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------

def test_neighbor_min_matches_bruteforce():
    st_ = random_graph(30, 4, 5)
    rng = np.random.default_rng(0)
    key = rng.uniform(0, 1, st_.n_vertices).astype(np.float32)
    got = np.asarray(neighbor_min(jnp.asarray(key),
                                  jnp.asarray(st_.senders),
                                  jnp.asarray(st_.receivers),
                                  st_.n_vertices))
    nbrs = [set() for _ in range(st_.n_vertices)]
    for u, v in zip(st_.senders, st_.receivers):
        nbrs[v].add(u)
        nbrs[u].add(v)
    expect = np.array([min((key[u] for u in nb), default=np.inf)
                       for nb in nbrs], np.float32)
    np.testing.assert_array_equal(got, expect)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(5, 40), seed=st.integers(0, 10**6))
def test_exclusion_min_radius2_excludes_self(n, seed):
    """exclusion_min at radius 2 = min rank over all *other* vertices within
    distance ≤ 2 — never the vertex's own rank echoed over v→u→v."""
    st_ = random_graph(n, 3, seed)
    rng = np.random.default_rng(seed)
    # unique finite ranks on a random subset
    rank = np.full(st_.n_vertices, np.inf, np.float32)
    sel = rng.uniform(0, 1, st_.n_vertices) < 0.6
    rank[sel] = rng.permutation(sel.sum()).astype(np.float32)
    got = np.asarray(exclusion_min(
        jnp.asarray(rank), jnp.asarray(st_.senders),
        jnp.asarray(st_.receivers), st_.n_vertices, 2))
    d2 = conflict_matrix(st_, 2)
    for v in range(st_.n_vertices):
        others = rank[d2[v]]
        expect = others.min() if others.size else np.inf
        assert got[v] == expect, (v, got[v], expect)


def test_pipeline_select_is_topk_with_id_ties():
    prio = jnp.asarray([0.5, 0.9, 0.9, 0.0, 0.2])
    selected, top_idx = pipeline_select(prio, 2, TOL)
    assert np.asarray(selected).tolist() == [False, True, True, False, False]
    rank = np.asarray(pipeline_ranks(prio, top_idx, TOL))
    assert rank[1] == 0.0 and rank[2] == 1.0  # tie broken toward lower id
    assert np.isinf(rank[[0, 3, 4]]).all()


def test_marker_wave_floods_both_directions():
    st_, _ = GraphStructure.from_edges([0, 1, 2], [1, 2, 3], 5)
    pending = jnp.zeros(5, bool).at[2].set(True)
    done = jnp.zeros(5, bool)
    frontier, new_pending = marker_wave(pending, done, st_)
    assert np.asarray(frontier).tolist() == [False, False, True, False, False]
    # both the in-neighbor (1) and the out-neighbor (3) get marked; 4 is
    # isolated and stays unmarked
    assert np.asarray(new_pending).tolist() == [False, True, True, True,
                                                False]


# ---------------------------------------------------------------------------
# engines consume the subsystem
# ---------------------------------------------------------------------------

def test_engine_schedulers_are_the_subsystem():
    st_ = random_graph(40, 4, 1)
    g = make_pagerank_graph(st_)
    prog = PageRankProgram(0.15, st_.n_vertices)
    from repro.core import BSPEngine, ChromaticEngine
    assert isinstance(BSPEngine(prog, g).scheduler, SweepScheduler)
    assert BSPEngine(prog, g).scheduler.num_phases == 1  # single color
    ce = ChromaticEngine(prog, g)
    assert isinstance(ce.scheduler, SweepScheduler)
    assert ce.scheduler.num_phases == ce.num_colors
    de = DynamicEngine(prog, g, pipeline_length=7)
    assert isinstance(de.scheduler, PriorityScheduler)
    assert de.scheduler.pipeline_length == 7


def test_dynamic_engine_full_consistency_converges():
    """Regression: distance-2 arbitration used to livelock every vertex
    with a neighbor (self-rank echoed over v→u→v); the fixed point must now
    be reached and match the exact solution."""
    st_ = random_graph(80, 4, 11)
    g = make_pagerank_graph(st_)
    prog = program_with(Consistency.FULL, st_.n_vertices)
    eng = DynamicEngine(prog, g, pipeline_length=16, tolerance=1e-7)
    s, _ = eng.run(eng.init(g), max_steps=5000)
    assert float(jnp.max(s.prio)) <= 1e-7, "FULL-consistency run livelocked"
    np.testing.assert_allclose(
        np.asarray(s.graph.vertex_data["rank"]),
        exact_pagerank(st_, 0.15, 500), atol=1e-5)


def test_engine_accepts_custom_scheduler():
    """The generic Engine runs any Scheduler — here FIFO and multi-queue
    drive PageRank to the same fixed point as the priority pipeline."""
    st_ = random_graph(60, 4, 2)
    g = make_pagerank_graph(st_)
    exact = exact_pagerank(st_, 0.15, 500)
    prog = PageRankProgram(0.15, st_.n_vertices)
    rng = np.random.default_rng(0)
    for sched in (
            FifoScheduler(prog, st_, 1e-7, pipeline_length=8),
            MultiQueueScheduler(prog, st_, 1e-7,
                                rng.integers(0, 3, st_.n_vertices),
                                pipeline_length=8)):
        eng = Engine(prog, g, tolerance=1e-7, scheduler=sched)
        s, _ = eng.run(eng.init(g), max_steps=5000)
        assert float(jnp.max(s.prio)) <= 1e-7
        np.testing.assert_allclose(
            np.asarray(s.graph.vertex_data["rank"]), exact, atol=1e-5)


def test_fifo_scheduler_serves_oldest_first():
    """With no rescheduling, FIFO at k=1 drains the initial queue in id
    order; re-entering vertices go to the back of the queue."""
    st_, _ = GraphStructure.from_edges([0, 1, 2, 3], [1, 2, 3, 4], 5)
    prog = PageRankProgram(0.15, 5)
    f = FifoScheduler(prog, st_, TOL, pipeline_length=1, serializable=False)
    prio = jnp.asarray([1.0, 1.0, 1.0, 0.0, 0.0])
    sched = f.init(prio)
    order = []
    for _ in range(3):
        mask, sched = f.select(sched, prio)
        order.append(int(np.asarray(mask).nonzero()[0][0]))
        prio, sched = f.reschedule(sched, prio, mask,
                                   jnp.zeros(5, jnp.float32))
    assert order == [0, 1, 2]
    # 0 re-enters at round 5 while 4 has waited since round 2: FIFO serves
    # the older entry first even though 0 has the lower id
    prio = prio.at[0].set(1.0).at[4].set(1.0)
    enq = np.asarray(sched["enq"]).copy()
    enq[0], enq[4] = 5, 2
    sched = {"enq": jnp.asarray(enq), "clock": sched["clock"]}
    mask, _ = f.select(sched, prio)
    assert int(np.asarray(mask).nonzero()[0][0]) == 4
