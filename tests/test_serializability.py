"""Serializability property tests (paper Sec. 3.4) — the core guarantee.

"A serializable execution implies that there exists a corresponding serial
schedule of update functions that when executed by Alg. 2 produces the same
values in the data-graph."  We check it *constructively*: the parallel
engines must match the SequentialEngine (the literal Alg. 2) executing the
induced serial schedule, via hypothesis over random graphs/params.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.apps.lbp import LoopyBPProgram, make_mrf_graph
from repro.apps.pagerank import PageRankProgram, make_pagerank_graph
from repro.core import (ChromaticEngine, Consistency, DynamicEngine,
                        SequentialEngine)
from repro.core.coloring import coloring_for, verify_coloring
from repro.core.graph import GraphStructure
from repro.graphs.generators import power_law_graph


def random_graph(n, avg_deg, seed):
    st_ = power_law_graph(n, avg_degree=avg_deg, seed=seed)
    if st_.n_edges == 0:  # degenerate draw: add one edge
        st_, _ = GraphStructure.undirected([0], [1], n)
    return st_


@settings(max_examples=10, deadline=None)
@given(n=st.integers(10, 60), seed=st.integers(0, 10**6))
def test_chromatic_equals_serial_schedule_pagerank(n, seed):
    """One chromatic sweep == the serial schedule (color asc, id asc)."""
    struct = random_graph(n, 4, seed)
    g = make_pagerank_graph(struct)
    prog = PageRankProgram(0.15, struct.n_vertices)

    eng = ChromaticEngine(prog, g, tolerance=1e-9)
    s = eng.init(g)
    s = eng.step(s)  # one sweep
    parallel = np.asarray(s.graph.vertex_data["rank"])

    seq = SequentialEngine(prog, g, tolerance=1e-9)
    colors = np.asarray(eng.colors)
    order = np.lexsort((np.arange(n), colors))
    # replicate the sweep semantics: execute scheduled vertices color-wise
    for v in order:
        if seq.prio[v] > seq.tolerance:
            seq.execute_vertex(int(v))
    np.testing.assert_allclose(parallel, seq.vdata["rank"],
                               rtol=1e-5, atol=1e-7)


@settings(max_examples=6, deadline=None)
@given(n=st.integers(8, 30), seed=st.integers(0, 10**6),
       k_states=st.integers(2, 4))
def test_chromatic_equals_serial_schedule_lbp(n, seed, k_states):
    """Edge-data writes (BP messages) also serialize correctly."""
    struct = random_graph(n, 3, seed)
    g = make_mrf_graph(struct, n_states=k_states, seed=seed % 97)
    prog = LoopyBPProgram(k_states, smoothing=0.5)

    eng = ChromaticEngine(prog, g, tolerance=1e-9)
    s = eng.step(eng.init(g))
    par_belief = np.asarray(s.graph.vertex_data["belief"])
    par_msg = np.asarray(s.graph.edge_data["msg"])

    seq = SequentialEngine(prog, g, tolerance=1e-9)
    colors = np.asarray(eng.colors)
    order = np.lexsort((np.arange(struct.n_vertices), colors))
    for v in order:
        if seq.prio[v] > seq.tolerance:
            seq.execute_vertex(int(v))
    np.testing.assert_allclose(par_belief, seq.vdata["belief"],
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(par_msg, seq.edata["msg"],
                               rtol=1e-4, atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(n=st.integers(10, 50), seed=st.integers(0, 10**6),
       pipeline=st.integers(1, 16))
def test_dynamic_engine_is_serializable(n, seed, pipeline):
    """Every dynamic-engine step's active set must admit a serial order —
    guaranteed if it is an independent set under the consistency model; we
    replay each step's set through the SequentialEngine and compare."""
    struct = random_graph(n, 4, seed)
    g = make_pagerank_graph(struct)
    prog = PageRankProgram(0.15, struct.n_vertices)
    eng = DynamicEngine(prog, g, pipeline_length=pipeline,
                        serializable=True, tolerance=1e-9)
    s = eng.init(g)
    seq = SequentialEngine(prog, g, tolerance=1e-9)

    for _ in range(5):
        prev_counts = np.asarray(s.update_count)
        s = eng.step(s)
        executed = np.nonzero(np.asarray(s.update_count) - prev_counts)[0]
        # independence under edge consistency: no two adjacent
        exec_set = set(executed.tolist())
        for u, v in zip(struct.senders, struct.receivers):
            assert not (int(u) in exec_set and int(v) in exec_set
                        and u != v), "adjacent vertices co-executed"
        seq.execute_schedule(executed)  # any order is equivalent
        np.testing.assert_allclose(
            np.asarray(s.graph.vertex_data["rank"]), seq.vdata["rank"],
            rtol=1e-5, atol=1e-7)


@settings(max_examples=8, deadline=None)
@given(n=st.integers(6, 40), seed=st.integers(0, 10**6),
       model=st.sampled_from([Consistency.EDGE, Consistency.FULL,
                              Consistency.VERTEX]))
def test_coloring_realizes_consistency_model(n, seed, model):
    """Paper Sec. 4.2.1: the coloring distance matches the model."""
    struct = random_graph(n, 4, seed)
    colors = coloring_for(struct, model)
    assert verify_coloring(struct, colors, model.exclusion_radius)
    if model == Consistency.VERTEX:
        assert colors.max() == 0


@settings(max_examples=10, deadline=None)
@given(n=st.integers(5, 80), seed=st.integers(0, 10**6))
def test_priority_order_respected_at_pipeline_1(n, seed):
    """pipeline_length=1 must execute the exact serial priority order
    (the shared-memory locking engine)."""
    struct = random_graph(n, 3, seed)
    g = make_pagerank_graph(struct)
    prog = PageRankProgram(0.15, struct.n_vertices)
    eng = DynamicEngine(prog, g, pipeline_length=1, tolerance=1e-9)
    s = eng.init(g)
    seq = SequentialEngine(prog, g, tolerance=1e-9)
    for _ in range(8):
        if float(jnp.max(s.prio)) <= 1e-9:
            break
        s = eng.step(s)
        seq.execute_vertex(int(np.argmax(seq.prio)))
    np.testing.assert_allclose(np.asarray(s.graph.vertex_data["rank"]),
                               seq.vdata["rank"], rtol=1e-5, atol=1e-7)
