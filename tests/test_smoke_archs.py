"""Per-architecture smoke tests (deliverable f): a REDUCED config of each
assigned arch runs one forward/train step on CPU — output shapes + no NaNs.

The FULL configs are exercised only via the dry-run (ShapeDtypeStructs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_arch
from repro.dist.sharding import TRAIN_RULES
from repro.graphs.generators import cora_like, molecule_batch

LM_ARCHS = ["starcoder2-3b", "deepseek-7b", "qwen3-32b",
            "moonshot-v1-16b-a3b", "olmoe-1b-7b"]
GNN_ARCHS = ["mace", "gat-cora", "equiformer-v2", "nequip"]


def test_registry_covers_all_assigned():
    assert len(ARCH_IDS) == 10
    for arch in ARCH_IDS:
        spec = get_arch(arch)
        assert spec.kind in ("lm", "moe", "gnn", "recsys")
        assert spec.full_config is not None


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_step(arch):
    from repro.models import transformer as tf
    spec = get_arch(arch)
    cfg = spec.smoke_config()
    params = tf.init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}

    (loss, metrics), grads = jax.jit(jax.value_and_grad(
        lambda p: tf.loss_fn(cfg, p, batch, TRAIN_RULES),
        has_aux=True))(params)
    assert np.isfinite(float(loss))
    for g in jax.tree.leaves(grads):
        assert not bool(jnp.isnan(g).any())

    logits, _ = jax.jit(lambda p: tf.forward(cfg, p, toks, TRAIN_RULES))(
        params)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_decode_step(arch):
    from repro.models import transformer as tf
    spec = get_arch(arch)
    cfg = spec.smoke_config()
    params = tf.init_params(cfg, jax.random.key(0))
    cache = tf.init_kv_cache(cfg, 2, 32, dtype=jnp.float32)
    tok = jnp.zeros((2, 1), jnp.int32)
    logits, cache = jax.jit(
        lambda p, c, t: tf.decode_step(cfg, p, c, t, 0, TRAIN_RULES))(
        params, cache, tok)
    assert logits.shape == (2, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_gnn_smoke_train_step(arch):
    from repro.launch.steps import GNN_MODULES
    from repro.models.gnn.api import make_graph_batch, gnn_loss
    spec = get_arch(arch)
    cfg = spec.smoke_config()
    mod = GNN_MODULES[cfg.kind]
    st = cora_like(64, 128, seed=0)
    batch = make_graph_batch(st, d_feat=cfg.d_feat, n_classes=cfg.n_classes)
    params = mod.init_params(cfg, jax.random.key(0))

    def loss(p):
        out = mod.forward(cfg, p, batch)
        assert out.shape == (64, cfg.n_classes)
        return gnn_loss(cfg, out, batch)

    l, grads = jax.jit(jax.value_and_grad(loss))(params)
    assert np.isfinite(float(l))
    for g in jax.tree.leaves(grads):
        assert not bool(jnp.isnan(g).any())


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_gnn_smoke_molecule_batch(arch):
    """graph_energy task over a block-diagonal molecular batch."""
    import dataclasses
    from repro.launch.steps import GNN_MODULES
    from repro.models.gnn.api import make_graph_batch, gnn_loss
    spec = get_arch(arch)
    cfg = dataclasses.replace(spec.smoke_config(), task="graph_energy",
                              n_graphs=4)
    mod = GNN_MODULES[cfg.kind]
    st, graph_id, pos = molecule_batch(batch=4, n_nodes=8, n_edges_per=12,
                                       seed=1)
    batch = make_graph_batch(st, d_feat=cfg.d_feat, n_classes=cfg.n_classes,
                             positions=pos, graph_id=graph_id)
    params = mod.init_params(cfg, jax.random.key(0))
    l = jax.jit(lambda p: gnn_loss(cfg, mod.forward(cfg, p, batch), batch))(
        params)
    assert np.isfinite(float(l))


def test_dlrm_smoke_train_and_serve():
    from repro.models import dlrm as dl
    spec = get_arch("dlrm-rm2")
    cfg = spec.smoke_config()
    params = dl.init_params(cfg, jax.random.key(0))
    B = 32
    batch = {
        "dense": jax.random.normal(jax.random.key(1), (B, cfg.n_dense)),
        "sparse_ids": jax.random.randint(
            jax.random.key(2), (B, cfg.n_sparse, cfg.multi_hot), 0,
            cfg.vocab_size),
        "labels": jnp.zeros((B,), jnp.int32),
    }
    (l, m), grads = jax.jit(jax.value_and_grad(
        lambda p: dl.loss_fn(cfg, p, batch, TRAIN_RULES), has_aux=True))(
        params)
    assert np.isfinite(float(l))
    logit = jax.jit(lambda p: dl.forward(cfg, p, batch, TRAIN_RULES))(params)
    assert logit.shape == (B,)
    assert not bool(jnp.isnan(logit).any())


def test_dlrm_smoke_retrieval():
    from repro.models import dlrm as dl
    spec = get_arch("dlrm-rm2")
    cfg = spec.smoke_config()
    params = dl.init_params(cfg, jax.random.key(0))
    batch = {
        "dense": jax.random.normal(jax.random.key(1), (1, cfg.n_dense)),
        "sparse_ids": jnp.zeros((1, cfg.n_sparse, cfg.multi_hot), jnp.int32),
        "candidates": jax.random.normal(jax.random.key(3),
                                        (4096, cfg.embed_dim)),
    }
    scores, idx = jax.jit(
        lambda p, b: dl.retrieval_score(cfg, p, b, TRAIN_RULES, top_k=16))(
        params, batch)
    assert scores.shape == (16,)
    # top-k is sorted descending
    assert bool(jnp.all(scores[:-1] >= scores[1:]))


def test_minibatch_sampler_feeds_gnn():
    """minibatch_lg path: real neighbor sampler -> padded batch -> GAT."""
    from repro.graphs.generators import power_law_graph
    from repro.graphs.sampling import NeighborSampler
    from repro.launch.steps import GNN_MODULES
    from repro.models.gnn.api import GNNConfig, gnn_loss
    st = power_law_graph(500, avg_degree=10, seed=0)
    sampler = NeighborSampler(st, fanout=(5, 3), seed=0)
    sub = sampler.sample(np.arange(16))
    cfg = GNNConfig(name="gat-mb", kind="gat", n_layers=2, d_hidden=4,
                    n_heads=2, d_feat=8, n_classes=3)
    mod = GNN_MODULES["gat"]
    rng = np.random.default_rng(0)
    batch = {
        "features": jnp.asarray(
            rng.normal(size=(sub.max_nodes, 8)), jnp.float32),
        "species": jnp.zeros((sub.max_nodes,), jnp.int32),
        "positions": jnp.zeros((sub.max_nodes, 3), jnp.float32),
        "senders": jnp.asarray(sub.senders),
        "receivers": jnp.asarray(sub.receivers),
        "edge_mask": jnp.asarray(sub.edge_mask),
        "node_mask": jnp.asarray(sub.node_mask),
        "graph_id": jnp.zeros((sub.max_nodes,), jnp.int32),
        "labels": jnp.zeros((sub.max_nodes,), jnp.int32),
    }
    params = mod.init_params(cfg, jax.random.key(0))
    out = jax.jit(lambda p: mod.forward(cfg, p, batch))(params)
    assert not bool(jnp.isnan(out).any())
    # padded (masked) edges must not contribute: perturb padded rows
    b2 = dict(batch)
    feats = np.asarray(batch["features"]).copy()
    feats[~np.asarray(sub.node_mask)] += 100.0
    b2["features"] = jnp.asarray(feats)
    out2 = jax.jit(lambda p: mod.forward(cfg, p, b2))(params)
    real = np.asarray(sub.node_mask)
    # messages only flow along real edges, so real-node outputs that have no
    # padded in-neighbors must match; seeds (first 16) qualify
    np.testing.assert_allclose(np.asarray(out)[:16], np.asarray(out2)[:16],
                               rtol=1e-4, atol=1e-4)
