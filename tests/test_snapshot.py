"""Fault-tolerance tests: async Chandy-Lamport snapshot invariants +
checkpoint manager (paper Sec. 4.3)."""
import os
import tempfile

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.apps.pagerank import PageRankProgram, make_pagerank_graph
from repro.checkpoint.manager import (CheckpointManager,
                                      checkpointing_worth_it, young_interval)
from repro.core import ChromaticEngine, DynamicEngine
from repro.core.snapshot import (AsyncSnapshotDriver, SyncSnapshotDriver,
                                 restore_engine_state)
from repro.graphs.generators import connected_power_law_graph as \
    connected_graph


class TestAsyncSnapshot:
    @settings(max_examples=6, deadline=None)
    @given(n=st.integers(10, 60), seed=st.integers(0, 10**6))
    def test_wave_property_and_single_save(self, n, seed):
        """Chandy-Lamport marker wave: for every edge (u, v),
        |save_step[u] - save_step[v]| <= 1 once both saved, every vertex is
        saved exactly once, and every edge is captured."""
        struct = connected_graph(n, seed=seed)
        g = make_pagerank_graph(struct)
        prog = PageRankProgram(0.15, n)
        eng = ChromaticEngine(prog, g, tolerance=1e-12)
        driver = AsyncSnapshotDriver(eng)
        state, snap, _ = driver.run(eng.init(g), max_steps=300,
                                    snapshot_at_step=1, initiators=(0,))
        assert snap is not None and bool(snap.complete)
        steps = np.asarray(snap.save_step)
        assert (steps >= 0).all()
        s, r = struct.senders, struct.receivers
        assert (np.abs(steps[s] - steps[r]) <= 1).all(), \
            "marker wave skipped a neighbor"
        assert bool(jnp.all(snap.saved_e_mask)), "some edge not captured"

    def test_restart_reaches_same_fixed_point(self):
        n = 80
        struct = connected_graph(n, seed=3)
        g = make_pagerank_graph(struct)
        prog = PageRankProgram(0.15, n)
        eng = ChromaticEngine(prog, g, tolerance=1e-10)
        driver = AsyncSnapshotDriver(eng)
        state, snap, _ = driver.run(eng.init(g), max_steps=500,
                                    snapshot_at_step=2)
        direct = np.asarray(state.graph.vertex_data["rank"])

        restored = restore_engine_state(eng, g, snap)
        restored, _ = eng.run(restored, max_steps=500)
        from_snap = np.asarray(restored.graph.vertex_data["rank"])
        np.testing.assert_allclose(direct, from_snap, atol=1e-7)

    def test_async_does_not_flatline(self):
        """Fig. 4(a): updates keep accumulating during the async snapshot,
        while the sync snapshot has paused steps."""
        n = 100
        struct = connected_graph(n, seed=5)
        g = make_pagerank_graph(struct)
        prog = PageRankProgram(0.15, n)

        eng = DynamicEngine(prog, g, pipeline_length=32, tolerance=1e-9)
        adriver = AsyncSnapshotDriver(eng)
        _, snap, atrace = adriver.run(eng.init(g), max_steps=400,
                                      snapshot_at_step=2)
        during = [t for t in atrace if 0 < t["snapshot_done_frac"] < 1.0]
        assert all(
            t2["total_updates"] > t1["total_updates"]
            for t1, t2 in zip(during, during[1:])), "async flatlined"

        eng2 = DynamicEngine(prog, g, pipeline_length=32, tolerance=1e-9)
        sdriver = SyncSnapshotDriver(eng2, capture_steps=3)
        _, sgraph, strace = sdriver.run(eng2.init(g), max_steps=400,
                                        snapshot_at_step=2)
        assert sgraph is not None
        assert sum(t.get("paused", 0) for t in strace) == 3


class TestCheckpointManager:
    def test_save_restore_roundtrip(self):
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, async_writes=True)
            state = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 4))}}
            mgr.save(10, state)
            mgr.save(20, jax.tree.map(lambda x: x * 2, state))
            mgr.wait()
            assert mgr.all_steps() == [10, 20]
            step, restored = mgr.restore(None, state)
            assert step == 20
            np.testing.assert_allclose(np.asarray(restored["a"]),
                                       np.arange(10.0) * 2)

    def test_gc_keeps_max(self):
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, max_to_keep=2, async_writes=False)
            for i in range(5):
                mgr.save(i, {"x": jnp.zeros(2)})
            assert mgr.all_steps() == [3, 4]

    def test_atomic_commit_no_torn_checkpoints(self):
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, async_writes=False)
            mgr.save(1, {"x": jnp.zeros(2)})
            # a torn dir (no COMMITTED marker) must be invisible
            os.makedirs(os.path.join(d, "ckpt_0000000099"))
            assert mgr.all_steps() == [1]

    def test_young_interval_paper_example(self):
        """Paper Sec. 4.3: 64 machines, MTBF 1 year/machine, ckpt 2 min
        -> interval ~3h (we get the same first-order value)."""
        t = young_interval(120.0, 365 * 24 * 3600.0, 64)
        assert 2.5 * 3600 < t < 4 * 3600
        # and the paper's conclusion: for experiments shorter than the
        # interval, checkpointing is not worth it
        assert not checkpointing_worth_it(
            20 * 60, 120.0, 365 * 24 * 3600.0, 64)


import jax  # noqa: E402  (used by tree.map above)
