"""Dynamic-graph ingestion tests (repro/stream/, DESIGN.md §3.11).

The subsystem's three contracts, each tested directly:

  1. **Zero recompilations** — applying a delta batch within capacity
     slack never retraces the jitted step (trace counters on every
     engine), and the GAS active-block bitmap confines post-delta work to
     the touched row blocks.
  2. **Incremental ≡ rebuild** — hypothesis property: converge a prefix,
     stream the remainder as delta batches, reconverge; the fixed point
     matches an engine built from scratch on the full graph (≤ 1e-5),
     across local/dist engines × PageRank/LBP × 2- and 4-machine meshes,
     including batches that force a ``regrow()``.
  3. **An atom file is a replayable delta stream** — journals written by
     ``core/partition.py:build_atoms`` replay through ``apply_delta`` into
     an empty streaming engine and reproduce the original graph's fixed
     point: loading and growing are the same operation.
"""
import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.apps.als import ALSProgram, als_rmse
from repro.apps.lbp import LoopyBPProgram
from repro.apps.pagerank import (PageRankProgram, exact_pagerank,
                                 make_pagerank_graph)
from repro.core import (ChromaticEngine, DataGraph, DynamicEngine, Engine,
                        UnsupportedStreamingError)
from repro.core.graph import GraphStructure
from repro.core.partition import build_atoms, overpartition
from repro.dist import DistributedEngine, DistributedLockingEngine
from repro.graphs.generators import power_law_graph
from repro.stream import (AddEdge, AddVertex, CapacityError, DelEdge,
                          DeltaBatch, DeltaJournal, DelVertex,
                          SetVertexData, SlackConfig, SnapshotInFlightError,
                          StreamingGraph, als_rating_arrivals, apply_delta,
                          apply_delta_growing, lbp_arrivals, lbp_churn,
                          make_dist_engine, make_local_engine,
                          pagerank_arrivals, pagerank_churn, readback,
                          stream_colors)

pytestmark = pytest.mark.skipif(
    jax.device_count() < 4, reason="needs 4 forced host devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")

ROOMY = SlackConfig(edge_frac=1.0, edge_min=8)


def _mesh(n):
    devs = np.asarray(jax.devices()[:n]).reshape(n, 1)
    return jax.sharding.Mesh(devs, ("data", "model"))


def _connected_power_law(n, deg, seed):
    """power_law_graph plus a path: the churn sources (and the snapshot
    marker wave) need every vertex reachable."""
    st_ = power_law_graph(n, avg_degree=deg, seed=seed)
    pairs = {(min(int(s), int(r)), max(int(s), int(r)))
             for s, r in zip(st_.senders, st_.receivers) if s != r}
    pairs |= {(i, i + 1) for i in range(n - 1)}
    a = np.asarray([p[0] for p in sorted(pairs)], np.int32)
    b = np.asarray([p[1] for p in sorted(pairs)], np.int32)
    st2, _ = GraphStructure.from_edges(np.concatenate([a, b]),
                                       np.concatenate([b, a]), n)
    return st2


# ---------------------------------------------------------------------------
# StreamingGraph unit behaviour
# ---------------------------------------------------------------------------

class TestStreamingGraph:
    def test_build_preserves_graph(self):
        st_ = power_law_graph(80, avg_degree=5, seed=0)
        sg, perm = StreamingGraph.build(st_)
        assert sg.n_real == 80 and sg.n_real_edges == st_.n_edges
        # capacity receivers sorted (the GAS invariant), real slots match
        assert (np.diff(sg.receivers) >= 0).all()
        assert np.array_equal(sg.senders[perm], st_.senders)
        assert np.array_equal(sg.receivers[perm], st_.receivers)
        # reverse links survive the slot mapping
        has = st_.reverse_perm >= 0
        assert np.array_equal(sg.rev_idx[perm[has]],
                              perm[st_.reverse_perm[has]])
        # slack slots are inert self-loops, their own reverse
        slack = ~sg.edge_mask
        assert np.array_equal(sg.senders[slack], sg.receivers[slack])
        assert (sg.rev_idx[slack] == np.nonzero(slack)[0]).all()
        cap = sg.capacity_structure()
        assert cap.is_symmetric() == st_.is_symmetric()

    def test_add_edge_links_reverse_and_degrees(self):
        st_, _ = GraphStructure.undirected([0, 1], [1, 2], 5)
        sg, _ = StreamingGraph.build(st_, SlackConfig(edge_min=4,
                                                      vertex_min=2))
        a = sg.add_edge(3, 4)
        assert sg.rev_idx[a] == -1
        b = sg.add_edge(4, 3)
        assert sg.rev_idx[a] == b and sg.rev_idx[b] == a
        assert sg.out_deg[3] == 1 and sg.fill[4] == 1
        with pytest.raises(ValueError):
            sg.add_edge(3, 4)  # duplicate

    def test_capacity_errors(self):
        st_, _ = GraphStructure.undirected([0], [1], 3)
        sg, _ = StreamingGraph.build(
            st_, SlackConfig(edge_min=1, vertex_min=1, edge_frac=0.0,
                             vertex_frac=0.0))
        sg.add_edge(2, 1)  # fills vertex 1's single slack slot
        with pytest.raises(CapacityError):
            sg.add_edge(1, 1)
        v = sg.add_vertex()
        assert v == 3
        with pytest.raises(CapacityError):
            sg.add_vertex()

    def test_compact_roundtrip(self):
        st_ = power_law_graph(60, avg_degree=4, seed=1)
        g = make_pagerank_graph(st_)
        sg, perm = StreamingGraph.build(st_)
        from repro.stream import pad_edge_data, pad_vertex_data
        vd = pad_vertex_data(g.vertex_data, sg.n_cap)
        ed = pad_edge_data(g.edge_data, sg, perm)
        out = sg.compact(vd, ed)
        assert out.structure.n_vertices == 60
        assert out.structure.n_edges == st_.n_edges
        # same edge multiset with matching weights
        key = lambda s_, r_: np.asarray(s_, np.int64) * 60 + r_
        a = np.sort(key(out.structure.senders, out.structure.receivers))
        b = np.sort(key(st_.senders, st_.receivers))
        assert np.array_equal(a, b)


class TestDeletion:
    def test_del_edge_swap_keeps_region_contiguous(self):
        st_, _ = GraphStructure.undirected([0, 1, 2], [1, 2, 3], 5)
        sg, _ = StreamingGraph.build(st_, SlackConfig(edge_min=4,
                                                      vertex_min=2))
        # give vertex 1 a second in-edge so deleting the first swaps
        sg.add_edge(3, 1)
        n0 = sg.n_real_edges
        slot, moved_from = sg.del_edge(0, 1)
        assert sg.n_real_edges == n0 - 1
        assert (0, 1) not in sg.edge_slot
        # the region tail moved into the hole; the vacated slot is inert
        assert moved_from is not None
        assert sg.senders[slot] == 3 and sg.edge_slot[(3, 1)] == slot
        assert not sg.edge_mask[moved_from]
        assert sg.senders[moved_from] == 1  # inert self-loop of dst
        assert sg.rev_idx[moved_from] == moved_from
        # the surviving twin (1, 0) lost its reverse link
        assert sg.rev_idx[sg.slot_of(1, 0)] == -1
        # the in-region stays contiguous: fill occupied slots, no holes
        occ = sg.in_slots(1)
        assert sg.edge_mask[occ].all() and len(occ) == 2
        with pytest.raises(KeyError):
            sg.del_edge(0, 1)  # already gone

    def test_delete_then_readd_relinks_reverse(self):
        st_, _ = GraphStructure.undirected([0, 1], [1, 2], 4)
        sg, _ = StreamingGraph.build(st_, SlackConfig(edge_min=4,
                                                      vertex_min=2))
        sg.del_edge(0, 1)
        a = sg.add_edge(0, 1)
        b = sg.slot_of(1, 0)
        assert sg.rev_idx[a] == b and sg.rev_idx[b] == a

    def test_del_vertex_requires_isolation_then_frees_slot(self):
        st_, _ = GraphStructure.undirected([0, 1], [1, 2], 4)
        sg, _ = StreamingGraph.build(st_, SlackConfig(edge_min=4,
                                                      vertex_min=2))
        with pytest.raises(ValueError):
            sg.del_vertex(2)  # still has incident edges
        sg.del_edge(1, 2)
        sg.del_edge(2, 1)
        sg.del_vertex(2)
        assert not sg.vertex_active[2]
        # the freed id is reusable by a later AddVertex
        assert sg.add_vertex() == 2
        assert sg.vertex_active[2]




class TestZeroRecompile:
    def test_local_fused_and_dense(self):
        st_ = power_law_graph(200, avg_degree=5, seed=1)
        prefix_g, batches, _ = pagerank_arrivals(st_, prefix_frac=0.85,
                                                 n_batches=3, seed=0)
        prog = PageRankProgram(0.15, st_.n_vertices)
        for fused in (True, False):
            eng, state = make_local_engine(prog, prefix_g, tolerance=1e-6,
                                           slack=ROOMY, use_fused=fused)
            state, _ = eng.run(state, max_steps=100)
            before = eng._trace_count
            assert before >= 1
            for b in batches:
                state = apply_delta(eng, state, b)
                state, _ = eng.run(state, max_steps=100)
            assert eng._trace_count == before, (
                "delta application retraced the jitted step")

    def test_dist_engines(self, cpu_mesh):
        st_ = power_law_graph(150, avg_degree=5, seed=2)
        prefix_g, batches, _ = pagerank_arrivals(st_, prefix_frac=0.85,
                                                 n_batches=2, seed=0)
        prog = PageRankProgram(0.15, st_.n_vertices)
        for cls, kw in [(DistributedEngine, {}),
                        (DistributedLockingEngine,
                         {"pipeline_length": 32})]:
            eng, state = make_dist_engine(prog, prefix_g, cpu_mesh,
                                          engine_cls=cls, tolerance=1e-6,
                                          slack=ROOMY, **kw)
            state, _ = eng.run(state, max_steps=200)
            before = eng._trace_count
            assert before >= 1
            for b in batches:
                state = apply_delta(eng, state, b)
                state, _ = eng.run(state, max_steps=200)
            assert eng._trace_count == before, cls.__name__

    def test_small_delta_activates_few_row_blocks(self):
        """The GAS active-block wiring: reconverging a one-edge delta must
        stream far fewer edges per step than full sweeps do — only the row
        blocks holding the re-seeded scopes are gathered."""
        st_ = power_law_graph(6000, avg_degree=5, seed=3)
        g = make_pagerank_graph(st_)
        prog = PageRankProgram(0.8, st_.n_vertices)  # strong teleport
        eng, state = make_local_engine(prog, g, tolerance=1e-6, slack=ROOMY)
        assert eng.use_fused
        state, _ = eng.run(state, max_steps=100)
        steps0, touched0 = int(state.step_index), int(state.edges_touched)
        per_sweep = touched0 / max(steps0, 1)

        # two low-degree endpoints: their closed neighborhoods span only a
        # handful of the ~47 row blocks
        deg = st_.in_degree + st_.out_degree
        u = int(np.argmin(deg[: 3000]))
        v = int(np.argmin(deg[3000:])) + 3000
        batch = DeltaBatch([AddEdge(u, v), AddEdge(v, u)])
        state = apply_delta(eng, state, batch)
        state, _ = eng.run(state, max_steps=100)
        steps1 = int(state.step_index) - steps0
        touched1 = int(state.edges_touched) - touched0
        assert steps1 >= 1
        # post-delta steps touch a small fraction of the edge set
        assert touched1 / steps1 < 0.5 * per_sweep, (
            touched1 / steps1, per_sweep)


# ---------------------------------------------------------------------------
# contract 2: incremental ≡ rebuild (the hypothesis property)
# ---------------------------------------------------------------------------

def _pagerank_case(n, seed, prefix_frac, n_batches):
    st_ = power_law_graph(n, avg_degree=5, seed=seed)
    prefix_g, batches, full_g = pagerank_arrivals(
        st_, prefix_frac=prefix_frac, n_batches=n_batches, seed=seed)
    prog = PageRankProgram(0.15, st_.n_vertices)
    scratch = Engine(prog, full_g, tolerance=1e-7)
    s, _ = scratch.run(scratch.init(full_g), max_steps=300)
    ref = np.asarray(s.graph.vertex_data["rank"])
    return prog, prefix_g, batches, ref, "rank", 1e-7, 300


def _lbp_case(n, seed, prefix_frac, n_batches):
    st_ = power_law_graph(n, avg_degree=4, seed=seed)
    prefix_g, batches, full_g = lbp_arrivals(
        st_, 3, prefix_frac=prefix_frac, n_batches=n_batches, seed=seed)
    prog = LoopyBPProgram(3, smoothing=0.7)
    scratch = ChromaticEngine(prog, full_g, tolerance=1e-6)
    s, _ = scratch.run(scratch.init(full_g), max_steps=80)
    ref = np.asarray(s.graph.vertex_data["belief"])
    return prog, prefix_g, batches, ref, "belief", 1e-6, 80


class TestIncrementalEquivalence:
    @settings(max_examples=3, deadline=None)
    @given(seed=st.integers(0, 100), case=st.sampled_from(["pr", "lbp"]))
    def test_local(self, seed, case):
        make = _pagerank_case if case == "pr" else _lbp_case
        prog, prefix_g, batches, ref, k, tol, steps = make(
            90, seed % 7, 0.85, 2)
        cls = Engine if case == "pr" else ChromaticEngine
        eng, state = make_local_engine(prog, prefix_g, engine_cls=cls,
                                       tolerance=tol, slack=ROOMY)
        state, _ = eng.run(state, max_steps=steps)
        for b in batches:
            state = apply_delta(eng, state, b)
            state, _ = eng.run(state, max_steps=steps)
        out = np.asarray(readback(eng, state).vertex_data[k])
        assert np.abs(out - ref).max() <= 1e-5

    @settings(max_examples=2, deadline=None)
    @given(seed=st.integers(0, 100), case=st.sampled_from(["pr", "lbp"]),
           n_machines=st.sampled_from([2, 4]))
    def test_dist_sweep(self, seed, case, n_machines):
        make = _pagerank_case if case == "pr" else _lbp_case
        prog, prefix_g, batches, ref, k, tol, steps = make(
            80, seed % 5, 0.85, 2)
        eng, state = make_dist_engine(prog, prefix_g, _mesh(n_machines),
                                      tolerance=tol, slack=ROOMY)
        state, _ = eng.run(state, max_steps=steps * eng.num_colors)
        for b in batches:
            state = apply_delta(eng, state, b)
            state, _ = eng.run(state, max_steps=steps * eng.num_colors)
        out = np.asarray(readback(eng, state).vertex_data[k])
        assert np.abs(out - ref).max() <= 1e-5

    @settings(max_examples=2, deadline=None)
    @given(seed=st.integers(0, 100), n_machines=st.sampled_from([2, 4]))
    def test_dist_locking(self, seed, n_machines):
        prog, prefix_g, batches, ref, k, tol, steps = _pagerank_case(
            80, seed % 5, 0.85, 2)
        eng, state = make_dist_engine(
            prog, prefix_g, _mesh(n_machines),
            engine_cls=DistributedLockingEngine, pipeline_length=1024,
            tolerance=tol, slack=ROOMY)
        state, _ = eng.run(state, max_steps=400)
        for b in batches:
            state = apply_delta(eng, state, b)
            state, _ = eng.run(state, max_steps=400)
        out = np.asarray(readback(eng, state).vertex_data[k])
        assert np.abs(out - ref).max() <= 1e-5

    @settings(max_examples=2, deadline=None)
    @given(seed=st.integers(0, 100), kind=st.sampled_from(["local", "dist"]))
    def test_regrow_forced(self, seed, kind):
        """A batch exceeding the (deliberately tiny) slack must regrow
        through the atom path and still land on the scratch fixed point."""
        prog, prefix_g, batches, ref, k, tol, steps = _pagerank_case(
            90, seed % 5, 0.8, 2)
        tiny = SlackConfig(edge_frac=0.0, edge_min=1, vertex_min=1,
                           ghost_slack=1, eghost_slack=1)
        if kind == "local":
            eng, state = make_local_engine(prog, prefix_g, tolerance=tol,
                                           slack=tiny)
        else:
            eng, state = make_dist_engine(prog, prefix_g, _mesh(2),
                                          tolerance=tol, slack=tiny)
        state, _ = eng.run(state, max_steps=300)
        regrew = 0
        for b in batches:
            eng, state, rg = apply_delta_growing(eng, state, b)
            regrew += rg
            state, _ = eng.run(state, max_steps=300)
        assert regrew >= 1, "tiny slack was expected to force a regrow"
        out = np.asarray(readback(eng, state).vertex_data[k])
        assert np.abs(out - ref).max() <= 1e-5


# ---------------------------------------------------------------------------
# full lifecycle: delete ≡ rebuild (the hypothesis property)
# ---------------------------------------------------------------------------

def _pagerank_churn_case(n, seed):
    st_ = _connected_power_law(n, 5, seed)
    full_g, batches, post_g, dead = pagerank_churn(
        st_, frac_del_edges=0.2, n_del_vertices=2, n_batches=2, seed=seed)
    prog = PageRankProgram(0.15, st_.n_vertices)
    scratch = Engine(prog, post_g, tolerance=1e-7)
    s, _ = scratch.run(scratch.init(post_g), max_steps=300)
    ref = np.asarray(s.graph.vertex_data["rank"])
    alive = np.setdiff1d(np.arange(st_.n_vertices), np.asarray(dead))
    return prog, full_g, batches, ref, alive, "rank", 1e-7, 300


def _lbp_churn_case(n, seed):
    st_ = _connected_power_law(n, 4, seed)
    full_g, batches, post_g, dead = lbp_churn(
        st_, 3, frac_del_edges=0.2, n_del_vertices=2, n_batches=2,
        seed=seed)
    prog = LoopyBPProgram(3, smoothing=0.7)
    scratch = ChromaticEngine(prog, post_g, tolerance=1e-6)
    s, _ = scratch.run(scratch.init(post_g), max_steps=80)
    ref = np.asarray(s.graph.vertex_data["belief"])
    alive = np.setdiff1d(np.arange(st_.n_vertices), np.asarray(dead))
    return prog, full_g, batches, ref, alive, "belief", 1e-6, 80


class TestDeleteEquivalence:
    """Converge the full graph, stream deletion batches (edges, whole
    vertices, renormalized weights), reconverge — the fixed point over the
    surviving vertices matches an engine built from scratch on the
    post-deletion graph (deleted ids stay behind as isolated slots)."""

    @settings(max_examples=3, deadline=None)
    @given(seed=st.integers(0, 100), case=st.sampled_from(["pr", "lbp"]))
    def test_local(self, seed, case):
        make = _pagerank_churn_case if case == "pr" else _lbp_churn_case
        prog, full_g, batches, ref, alive, k, tol, steps = make(
            90, seed % 7)
        cls = Engine if case == "pr" else ChromaticEngine
        eng, state = make_local_engine(prog, full_g, engine_cls=cls,
                                       tolerance=tol, slack=ROOMY)
        state, _ = eng.run(state, max_steps=steps)
        for b in batches:
            assert b.n_deletions > 0
            state = apply_delta(eng, state, b)
            state, _ = eng.run(state, max_steps=steps)
        out = np.asarray(readback(eng, state).vertex_data[k])
        assert np.abs(out[alive] - ref[alive]).max() <= 1e-5

    @settings(max_examples=2, deadline=None)
    @given(seed=st.integers(0, 100), case=st.sampled_from(["pr", "lbp"]),
           n_machines=st.sampled_from([2, 4]))
    def test_dist(self, seed, case, n_machines):
        make = _pagerank_churn_case if case == "pr" else _lbp_churn_case
        prog, full_g, batches, ref, alive, k, tol, steps = make(
            80, seed % 5)
        eng, state = make_dist_engine(prog, full_g, _mesh(n_machines),
                                      tolerance=tol, slack=ROOMY)
        state, _ = eng.run(state, max_steps=steps * eng.num_colors)
        for b in batches:
            state = apply_delta(eng, state, b)
            state, _ = eng.run(state, max_steps=steps * eng.num_colors)
        out = np.asarray(readback(eng, state).vertex_data[k])
        assert np.abs(out[alive] - ref[alive]).max() <= 1e-5


# ---------------------------------------------------------------------------
# incremental color repair (DESIGN §3.12)
# ---------------------------------------------------------------------------

def _assert_no_conflicts(sg, colors):
    bad = [(s, r) for (s, r) in sg.edge_slot
           if s != r and colors[s] == colors[r]]
    assert not bad, f"same-color conflicting edges survived: {bad[:5]}"


class TestColorRepair:
    """Delta edges joining same-colored vertices must be repaired at
    apply_delta time — between regrows, the live coloring stays a proper
    coloring for every radius ≥ 1 program."""

    def test_local_lbp_arrivals(self):
        st_ = power_law_graph(120, avg_degree=4, seed=9)
        prefix_g, batches, _ = lbp_arrivals(st_, 3, prefix_frac=0.8,
                                            n_batches=3, seed=1)
        prog = LoopyBPProgram(3, smoothing=0.7)
        eng, state = make_local_engine(prog, prefix_g,
                                       engine_cls=ChromaticEngine,
                                       tolerance=1e-6, slack=ROOMY)
        assert eng.num_colors > int(stream_colors(eng).max()) + 1, \
            "color slack should reserve spare phases"
        for b in batches:
            state = apply_delta(eng, state, b)
            _assert_no_conflicts(eng._stream_graph, stream_colors(eng))
        state, _ = eng.run(state, max_steps=80)

    def test_dist_lbp_arrivals(self, cpu_mesh):
        st_ = power_law_graph(100, avg_degree=4, seed=10)
        prefix_g, batches, _ = lbp_arrivals(st_, 3, prefix_frac=0.8,
                                            n_batches=2, seed=2)
        prog = LoopyBPProgram(3, smoothing=0.7)
        eng, state = make_dist_engine(prog, prefix_g, cpu_mesh,
                                      tolerance=1e-6, slack=ROOMY)
        for b in batches:
            state = apply_delta(eng, state, b)
            _assert_no_conflicts(eng._stream_graph, stream_colors(eng))
        state, _ = eng.run(state, max_steps=200)


# ---------------------------------------------------------------------------
# snapshot × delta fence (the fixed undefined-behavior hole)
# ---------------------------------------------------------------------------

class TestSnapshotFence:
    def test_apply_delta_rejected_while_marker_wave_in_flight(self,
                                                              cpu_mesh):
        st_ = _connected_power_law(80, 4, seed=11)
        full_g, batches, _, _ = pagerank_churn(st_, frac_del_edges=0.15,
                                               n_del_vertices=1,
                                               n_batches=1, seed=0)
        prog = PageRankProgram(0.15, st_.n_vertices)
        eng, state = make_dist_engine(prog, full_g, cpu_mesh,
                                      tolerance=1e-6, slack=ROOMY)
        state, _ = eng.run(state, max_steps=200)
        state = eng.start_snapshot(state, (0,))
        sg = eng._stream_graph
        before = sg.n_real_edges
        with pytest.raises(SnapshotInFlightError):
            apply_delta(eng, state, batches[0])
        assert sg.n_real_edges == before, "fence must reject pre-mutation"
        # drain the wave; afterwards the same batch applies cleanly
        for _ in range(200):
            if eng.snapshot_complete(state):
                break
            state = eng.step(state)
        assert eng.snapshot_complete(state)
        state = eng.clear_snapshot(state)
        state = apply_delta(eng, state, batches[0])
        state, _ = eng.run(state, max_steps=200)


# ---------------------------------------------------------------------------
# engines that cannot stream say so at construction
# ---------------------------------------------------------------------------

class TestUnsupportedStreaming:
    def test_dynamic_engine_rejected_at_construction(self):
        st_ = power_law_graph(40, avg_degree=4, seed=12)
        g = make_pagerank_graph(st_)
        prog = PageRankProgram(0.15, st_.n_vertices)
        with pytest.raises(UnsupportedStreamingError):
            make_local_engine(prog, g, engine_cls=DynamicEngine,
                              tolerance=1e-6, slack=ROOMY)
        # the same engine still builds fine on static structure
        DynamicEngine(prog, g, tolerance=1e-6)


# ---------------------------------------------------------------------------
# DeltaJournal: durable, offset-ordered, gap-checked
# ---------------------------------------------------------------------------

class TestDeltaJournal:
    def _batches(self):
        return [
            DeltaBatch([AddVertex(vid=7),
                        AddEdge(0, 1, data=[np.float32(0.5)]),
                        SetVertexData(2, [np.asarray([0.25], np.float32)])]),
            DeltaBatch([DelEdge(0, 1), DelVertex(7)]),
        ]

    def test_roundtrip_through_reopen(self, tmp_path):
        j = DeltaJournal(str(tmp_path))
        assert j.next_offset == 0
        for b in self._batches():
            j.append(b)
        j2 = DeltaJournal(str(tmp_path))  # fresh scan of the directory
        assert len(j2) == 2 and j2.next_offset == 2
        got = list(j2.read_since(0))
        assert [k for k, _ in got] == [0, 1]
        for (_, rb), b in zip(got, self._batches()):
            assert [type(c) for c in rb] == [type(c) for c in b]
        b0 = got[0][1]
        assert b0.commands[0].vid == 7
        assert (b0.commands[1].src, b0.commands[1].dst) == (0, 1)
        np.testing.assert_allclose(b0.commands[1].data[0], 0.5)
        np.testing.assert_allclose(b0.commands[2].data[0], [0.25])
        b1 = got[1][1]
        assert (b1.commands[0].src, b1.commands[0].dst) == (0, 1)
        assert b1.commands[1].vid == 7
        # read_since(1) is the replay suffix of a cut anchored at 1
        assert [k for k, _ in j2.read_since(1)] == [1]

    def test_gap_detection(self, tmp_path):
        j = DeltaJournal(str(tmp_path))
        for b in self._batches():
            j.append(b)
        import os
        os.unlink(os.path.join(str(tmp_path), "delta_0000000000.npz"))
        with pytest.raises(ValueError, match="gap"):
            DeltaJournal(str(tmp_path))

    def test_torn_final_entry_truncated_with_warning(self, tmp_path):
        """ISSUE 7 satellite 2: a torn *tail* (truncated bytes — power
        loss after rename, a bad copy) is warned about and truncated on
        reopen; the surviving prefix stays fully readable and appendable."""
        import os
        j = DeltaJournal(str(tmp_path))
        for b in self._batches() + [DeltaBatch([AddVertex(vid=9)])]:
            j.append(b)
        last = os.path.join(str(tmp_path), "delta_0000000002.npz")
        with open(last, "r+b") as f:
            f.truncate(os.path.getsize(last) // 2)
        with pytest.warns(RuntimeWarning, match="torn final entry"):
            j2 = DeltaJournal(str(tmp_path))
        assert j2.next_offset == 2
        assert not os.path.exists(last)  # the torn bytes are gone
        # the committed prefix is intact and the log accepts new appends
        assert [k for k, _ in j2.read_since(0)] == [0, 1]
        assert j2.append(DeltaBatch([AddVertex(vid=11)])) == 2
        assert j2.read(2).commands[0].vid == 11
        # double-crash: two torn tails in a row truncate twice
        for off in (1, 2):
            p = os.path.join(str(tmp_path), f"delta_000000000{off}.npz")
            with open(p, "r+b") as f:
                f.truncate(4)
        with pytest.warns(RuntimeWarning, match="torn final entry"):
            j3 = DeltaJournal(str(tmp_path))
        assert j3.next_offset == 1
        assert [k for k, _ in j3.read_since(0)] == [0]

    def test_journal_records_committed_batches_only(self, tmp_path):
        """attach_journal + apply_delta: committed batches append under
        monotone offsets; a batch that fails capacity is not recorded."""
        from repro.stream import attach_journal
        st_ = power_law_graph(60, avg_degree=4, seed=13)
        g = make_pagerank_graph(st_)
        prog = PageRankProgram(0.15, st_.n_vertices)
        tiny = SlackConfig(edge_frac=0.0, edge_min=1, vertex_min=1,
                           ghost_slack=1, eghost_slack=1)
        eng, state = make_local_engine(prog, g, tolerance=1e-6, slack=tiny)
        journal = DeltaJournal(str(tmp_path))
        attach_journal(eng, journal)
        sg = eng._stream_graph
        ok = next(i for i in range(1, 59)
                  if (i, 0) not in sg.edge_slot and sg.fill[0] <
                  sg.slot_start[1] - sg.slot_start[0])
        state = apply_delta(eng, state, DeltaBatch([AddEdge(ok, 0)]))
        assert journal.next_offset == 1 and eng._stream_offset == 1
        fresh = [i for i in range(1, 59) if (i, 0) not in sg.edge_slot][:6]
        with pytest.raises(CapacityError):
            apply_delta(eng, state, DeltaBatch(
                [AddEdge(i, 0) for i in fresh]))
        assert journal.next_offset == 1, "failed batch must not journal"


# ---------------------------------------------------------------------------
# capacity-error atomicity
# ---------------------------------------------------------------------------

class TestCapacityAtomicity:
    def test_failed_batch_leaves_state_unchanged(self, cpu_mesh):
        st_ = power_law_graph(60, avg_degree=4, seed=5)
        g = make_pagerank_graph(st_)
        prog = PageRankProgram(0.15, st_.n_vertices)
        tiny = SlackConfig(edge_frac=0.0, edge_min=1, vertex_min=1,
                           ghost_slack=1, eghost_slack=1)
        eng, state = make_dist_engine(prog, g, cpu_mesh, tolerance=1e-6,
                                      slack=tiny)
        state, _ = eng.run(state, max_steps=100)
        ref = readback(eng, state)
        sg = eng._stream_graph
        n_edges_before = sg.n_real_edges
        # overload one vertex's region mid-batch (fresh senders only)
        fresh = [i for i in range(1, 59)
                 if (i, 0) not in sg.edge_slot][:5]
        bad = DeltaBatch([AddEdge(i, 0) for i in fresh])
        with pytest.raises(CapacityError):
            apply_delta(eng, state, bad)
        assert sg.n_real_edges == n_edges_before
        # the engine still steps and the state is untouched
        out = readback(eng, state)
        assert np.array_equal(np.asarray(out.vertex_data["rank"]),
                              np.asarray(ref.vertex_data["rank"]))
        eng.step(state)


# ---------------------------------------------------------------------------
# contract 3: atom journals replay as delta streams
# ---------------------------------------------------------------------------

class TestAtomReplay:
    def test_journal_replay_reaches_scratch_fixed_point(self, tmp_path):
        st_ = power_law_graph(70, avg_degree=4, seed=6)
        g = make_pagerank_graph(st_)
        atom_of = overpartition(st_, 6, seed=0)
        index = build_atoms(g, atom_of, str(tmp_path))

        empty_st, _ = GraphStructure.from_edges(
            np.zeros(0, np.int32), np.zeros(0, np.int32), 0)
        empty = DataGraph.build(
            empty_st,
            jax.tree.map(lambda x: np.asarray(x)[:0], g.vertex_data),
            jax.tree.map(lambda x: np.asarray(x)[:0], g.edge_data))
        prog = PageRankProgram(0.15, st_.n_vertices)
        eng, state = make_local_engine(
            prog, empty, tolerance=1e-7, slack=SlackConfig(edge_min=2),
            n_cap=st_.n_vertices + 4,
            in_capacity=st_.in_degree.astype(np.int64) + 2)
        for path in index.files:
            batch = DeltaBatch.from_atom_file(path)
            state = apply_delta(eng, state, batch)
        state, _ = eng.run(state, max_steps=300)
        out = np.asarray(readback(eng, state).vertex_data["rank"])
        exact = exact_pagerank(st_, 0.15, iters=500)
        assert out.shape[0] == st_.n_vertices
        assert np.abs(out - exact).max() <= 1e-5


# ---------------------------------------------------------------------------
# streaming ratings into ALS (the Sec. 5.1 workload)
# ---------------------------------------------------------------------------

class TestALSStreaming:
    def test_rating_stream_with_late_movies(self):
        prefix_g, batches, full_g, _ = als_rating_arrivals(
            50, 25, 400, d=4, prefix_frac=0.85, n_batches=2,
            n_late_movies=3, seed=0)
        assert sum(b.n_new_vertices for b in batches) == 3
        prog = ALSProgram(d=4)
        eng, state = make_local_engine(prog, prefix_g,
                                       engine_cls=ChromaticEngine,
                                       tolerance=1e-5, slack=ROOMY)
        state, _ = eng.run(state, max_steps=60)
        for b in batches:
            eng, state, _ = apply_delta_growing(eng, state, b)
            state, _ = eng.run(state, max_steps=60)
        stream_g = readback(eng, state)
        assert stream_g.structure.n_vertices == full_g.structure.n_vertices
        assert stream_g.structure.n_edges == full_g.structure.n_edges

        scratch = ChromaticEngine(prog, full_g, tolerance=1e-5)
        s2, _ = scratch.run(scratch.init(full_g), max_steps=60)
        # ALS fixed points are not unique (alternating least squares is
        # non-convex) — compare the quality metric, not the factors
        tr_s, tr_r = als_rmse(stream_g, True), als_rmse(s2.graph, True)
        assert tr_s <= tr_r + 0.05, (tr_s, tr_r)
        assert als_rmse(stream_g, False) <= 1.5
