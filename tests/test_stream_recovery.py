"""Crash ≡ uninterrupted for streaming engines (repro/stream/recovery.py).

The acceptance scenario of DESIGN.md §3.12: stream delta batches —
including DelEdge/DelVertex — into a live 4-machine engine, journal a
Chandy-Lamport cut anchored to a journal offset mid-stream, kill a
machine while later batches are in flight, recover from the latest cut +
journal replay, finish the stream.  The result must match an
uninterrupted run to 1e-5 on every surviving vertex, for PageRank and
LBP alike.
"""
import jax
import numpy as np
import pytest

from repro.apps.lbp import LoopyBPProgram
from repro.apps.pagerank import PageRankProgram
from repro.checkpoint.manager import CheckpointManager
from repro.core.graph import GraphStructure
from repro.graphs.generators import power_law_graph
from repro.stream import (DeltaJournal, SlackConfig, apply_delta_growing,
                          lbp_churn, make_dist_engine, pagerank_arrivals,
                          pagerank_churn, readback,
                          run_stream_kill_restore)

pytestmark = pytest.mark.skipif(
    jax.device_count() < 4, reason="needs 4 forced host devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")

ROOMY = SlackConfig(edge_frac=1.0, edge_min=8)


def _mesh(n):
    devs = np.asarray(jax.devices()[:n]).reshape(n, 1)
    return jax.sharding.Mesh(devs, ("data", "model"))


def _connected_power_law(n, deg, seed):
    st_ = power_law_graph(n, avg_degree=deg, seed=seed)
    pairs = {(min(int(s), int(r)), max(int(s), int(r)))
             for s, r in zip(st_.senders, st_.receivers) if s != r}
    pairs |= {(i, i + 1) for i in range(n - 1)}
    a = np.asarray([p[0] for p in sorted(pairs)], np.int32)
    b = np.asarray([p[1] for p in sorted(pairs)], np.int32)
    st2, _ = GraphStructure.from_edges(np.concatenate([a, b]),
                                       np.concatenate([b, a]), n)
    return st2


def _case(case):
    st_ = _connected_power_law(72, 4, seed=3)
    if case == "pr":
        full_g, batches, _, dead = pagerank_churn(
            st_, frac_del_edges=0.2, n_del_vertices=2, n_batches=3, seed=1)
        prog = PageRankProgram(0.15, st_.n_vertices)
        key, tol = "rank", 1e-7
    else:
        full_g, batches, _, dead = lbp_churn(
            st_, 3, frac_del_edges=0.2, n_del_vertices=2, n_batches=3,
            seed=1)
        prog = LoopyBPProgram(3, smoothing=0.7)
        key, tol = "belief", 1e-6
    alive = np.setdiff1d(np.arange(st_.n_vertices), np.asarray(dead))
    assert sum(b.n_deletions for b in batches) > 0
    return prog, full_g, batches, alive, key, tol


class TestCrashEqualsUninterrupted:
    @pytest.mark.parametrize("case", ["pr", "lbp"])
    def test_kill_restore_matches_uninterrupted(self, case, tmp_path):
        prog, full_g, batches, alive, key, tol = _case(case)
        mesh = _mesh(4)

        def build():
            return make_dist_engine(prog, full_g, mesh, tolerance=tol,
                                    slack=ROOMY)

        # uninterrupted reference: same build, same batches, no fault
        eng, state = build()
        state, _ = eng.run(state, max_steps=2000)
        for b in batches:
            eng, state, _ = apply_delta_growing(eng, state, b)
            state, _ = eng.run(state, max_steps=2000)
        ref = np.asarray(readback(eng, state).vertex_data[key])

        # chaos run: cut after batch 0, machine dies after batch 1 with
        # batch 2 still in flight — deltas land before AND after the cut
        journal = DeltaJournal(str(tmp_path / "journal"))
        manager = CheckpointManager(str(tmp_path / "ckpt"),
                                    async_writes=False)
        eng2, state2, info = run_stream_kill_restore(
            build, journal, manager, batches,
            snapshot_after=0, kill_after=1, machine=2)
        out = np.asarray(readback(eng2, state2).vertex_data[key])

        assert info["journal_offset"] == 1  # cut anchored after batch 0
        assert info["killed_machine"] == 2
        assert journal.next_offset == len(batches)
        assert np.abs(out[alive] - ref[alive]).max() <= 1e-5

    def test_regrow_between_cut_and_crash(self, tmp_path):
        """ISSUE 7 satellite 1: a batch after the cut exhausts the
        (deliberately tiny) slack, so the live run regrows its capacity
        layout *between the cut and the crash*.  Recovery replays the
        journal suffix with the same growth escalation, so it must regrow
        at the same batch and still match the uninterrupted run."""
        st_ = _connected_power_law(90, 4, seed=3)
        prefix_g, batches, _ = pagerank_arrivals(
            st_, prefix_frac=0.8, n_batches=3, seed=1)
        prog = PageRankProgram(0.15, st_.n_vertices)
        tiny = SlackConfig(edge_frac=0.0, edge_min=1, vertex_min=1,
                           ghost_slack=1, eghost_slack=1)
        mesh = _mesh(4)

        def build():
            return make_dist_engine(prog, prefix_g, mesh, tolerance=1e-7,
                                    slack=tiny)

        # uninterrupted reference under the same tiny slack + growth path
        eng, state = build()
        state, _ = eng.run(state, max_steps=2000)
        regrew_ref = []
        for i, b in enumerate(batches):
            eng, state, rg = apply_delta_growing(eng, state, b)
            if rg:
                regrew_ref.append(i)
            state, _ = eng.run(state, max_steps=2000)
        ref = np.asarray(readback(eng, state).vertex_data["rank"])
        assert any(i > 0 for i in regrew_ref), \
            "tiny slack was expected to force a regrow after batch 0"

        journal = DeltaJournal(str(tmp_path / "journal"))
        manager = CheckpointManager(str(tmp_path / "ckpt"),
                                    async_writes=False)
        eng2, state2, info = run_stream_kill_restore(
            build, journal, manager, batches,
            snapshot_after=0, kill_after=2, machine=1)
        out = np.asarray(readback(eng2, state2).vertex_data["rank"])

        # the regression: capacity changed between cut (after batch 0) and
        # crash (after batch 2), and recovery still lands on the reference
        assert any(i > info["journal_offset"] - 1
                   for i in info["regrown_live_batches"]), \
            f"no regrow between cut and crash: {info}"
        assert np.abs(out - ref).max() <= 1e-5
