"""Streaming × quantized wire (ISSUE 9; DESIGN.md §3.14 mirror-patch).

The tentpole contract: a lossy ``WireConfig`` is legal on the streaming
distributed engines because every splice patches the error-feedback
mirrors in lockstep with the caches it rewires, and the ghost exchange
can double-buffer against local compute.  Tested here:

  * streaming int8/bf16 (± overlap) ≡ the f32 streaming fixed point on
    PageRank and LBP, 4-machine mesh, with deletions on both sides of
    an in-batch ghost-slab regrow, backlog drained (the full
    ``regrow_engine`` rebuild × wire is covered by the mirror-patch
    property below, which forces it via a no-slack config);
  * hypothesis property: mirror-patched engine ≡ an engine rebuilt from
    scratch on the final live graph, streaming × int8/bf16 × 2/4-machine
    meshes, deletions + forced regrow included;
  * codec edge cases: all-zero rows, subnormal magnitudes, max-magnitude
    rows, NaN containment (a poisoned row never decodes to garbage);
  * a dead machine's NaN rows never reach survivors under the int8 wire;
  * live migration (leave after a dead machine, join) under a non-default
    wire reconverges to the f32 fixed point;
  * rollback atomicity when in-batch slab growth succeeds but a later
    splice in the same batch fails — host and device tables both restore;
  * the jaxpr overlap audit: the double-buffered build issues collectives
    before gathers that do not consume them; the sequential build blocks.
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.apps.lbp import LoopyBPProgram
from repro.apps.pagerank import PageRankProgram, make_pagerank_graph
from repro.checkpoint.manager import CheckpointManager
from repro.dist.engine import DistributedEngine, exchange_overlap_report
from repro.dist.faults import kill_machine, machine_data_lost
from repro.dist.migrate import migrate_join, migrate_leave
from repro.dist.snapshot import save_snapshot
from repro.dist.wire import WireConfig, decode_payload, encode_payload
from repro.graphs.generators import (connected_power_law_graph,
                                     power_law_graph)
from repro.stream import (AddEdge, DelEdge, DeltaBatch, SlackConfig,
                          apply_delta, apply_delta_growing, lbp_arrivals,
                          make_dist_engine, pagerank_arrivals, readback)

pytestmark = pytest.mark.skipif(
    jax.device_count() < 4, reason="needs 4 forced host devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")

# roomy edge slack but a single spare ghost cache line per slab pair, so
# a handful of new cross-machine edges forces in-batch slab growth
GROWY = SlackConfig(edge_frac=1.0, edge_min=8, ghost_slack=1,
                    eghost_slack=1)
TINY = SlackConfig(edge_frac=0.0, edge_min=1, vertex_min=1, ghost_slack=1,
                   eghost_slack=1)


def _mesh(n):
    devs = np.asarray(jax.devices()[:n]).reshape(n, 1)
    return jax.sharding.Mesh(devs, ("data", "model"))


def _cmd_vids(batches):
    vids = set()
    for b in batches:
        for c in b:
            for attr in ("src", "dst", "vid"):
                v = getattr(c, attr, None)
                if v is not None:
                    vids.add(int(v))
    return vids


def _del_batches(prefix_st, avoid, seed, k=3):
    """Two deletion batches (both directions per pair) over prefix edges
    whose endpoints no later command references — valid wherever they sit
    in the stream."""
    rng = np.random.default_rng(seed)
    pairs = sorted({(min(int(s), int(r)), max(int(s), int(r)))
                    for s, r in zip(prefix_st.senders, prefix_st.receivers)
                    if s != r and int(s) not in avoid
                    and int(r) not in avoid})
    assert len(pairs) >= 2 * k, "graph too small for the deletion plan"
    pick = rng.choice(len(pairs), size=2 * k, replace=False)
    out = []
    for half in (pick[:k], pick[k:]):
        cmds = []
        for i in half:
            a, b = pairs[int(i)]
            cmds += [DelEdge(a, b), DelEdge(b, a)]
        out.append(DeltaBatch(cmds))
    return out


def _growth_pairs(eng, extra=2):
    """New machine-0 → machine-1 edges, one more than slab (1, 0) has
    free cache lines, so the last claim must grow the slabs in place."""
    lay = eng.layout
    sg = eng._stream_graph
    S, B = lay.n_machines, lay.budget
    cached = {(d, int(v)) for d in range(S)
              for v in lay.ghost_gid.reshape(S, S, B)[d].ravel() if v >= 0}
    edges = {(int(s), int(r)) for s, r, m in
             zip(sg.senders, sg.receivers, sg.edge_mask) if m}
    mach = lay.machine_of
    free = len(eng._stream_patcher.ghost_free.get((1, 0), [])) \
        if eng._stream_patcher is not None else lay.budget
    want = free + extra
    out = []
    r_cands = [v for v in range(sg.n_real) if mach[v] == 1]
    used = {r: 0 for r in r_cands}  # spread in-edge load (edge_min slack)
    for s in range(sg.n_real):
        if mach[s] != 0 or (1, s) in cached:
            continue
        for r in sorted(r_cands, key=used.get):
            if s != r and (s, r) not in edges:
                out.append((s, r))
                used[r] += 1
                break
        if len(out) == want:
            break
    assert len(out) == want, "not enough cross-machine non-edges"
    return out


def _pr_stream(n, seed):
    st_ = connected_power_law_graph(n, seed=seed)
    prefix_g, adds, _ = pagerank_arrivals(st_, prefix_frac=0.85,
                                          n_batches=2, seed=seed)
    return PageRankProgram(0.15, n), prefix_g, adds, "rank", 1e-7, 500


def _lbp_stream(n, seed):
    st_ = power_law_graph(n, avg_degree=4, seed=seed)
    prefix_g, adds, _ = lbp_arrivals(st_, 3, prefix_frac=0.8,
                                     n_batches=2, seed=seed)
    # 2e-6, not 1e-6: smoothed LBP in f32 rounds into ~1.4e-6 limit
    # cycles near this workload's fixed point (the f32 arm shows the
    # same plateau, so it is the rounding floor, not a wire artifact)
    return LoopyBPProgram(3, smoothing=0.7), prefix_g, adds, "belief", \
        2e-6, 500


# ---------------------------------------------------------------------------
# tentpole: streaming quantized wire ≡ streaming f32, regrow included
# ---------------------------------------------------------------------------

class TestStreamingQuantizedEquivalence:
    def test_pagerank_deltas_across_slab_growth(self):
        """The acceptance scenario: 4-machine streaming PageRank, int8 and
        bf16 (and int8 + overlapped exchange) land within 1e-5 of the f32
        streaming fixed point across a delta sequence with deletions on
        both sides of a forced in-batch ghost-slab growth."""
        prog, prefix_g, adds, key, tol, steps = _pr_stream(72, 1)
        d1, d2 = _del_batches(prefix_g.structure, _cmd_vids(adds), 1)
        arms = {
            "f32": (None, False),
            "int8": (WireConfig(codec="int8", top_k=6), False),
            "bf16": (WireConfig(codec="bf16", top_k=6), False),
            "int8+ov": (WireConfig(codec="int8", top_k=6), True),
            "f32+ov": (None, True),
        }
        grow_batch = None
        outs = {}
        for name, (wire, overlap) in arms.items():
            eng, state = make_dist_engine(
                prog, prefix_g, _mesh(4), tolerance=tol, slack=GROWY,
                wire=wire, overlap=overlap)
            state, _ = eng.run(state, max_steps=steps)
            b0 = eng.layout.budget
            for batch in (d1, adds[0], "grow", adds[1], d2):
                if batch == "grow":
                    if grow_batch is None:
                        # layout evolution is deterministic and
                        # wire-independent: the first arm's plan replays
                        # bit-identically on every other arm
                        grow_batch = DeltaBatch(
                            [AddEdge(s, r)
                             for s, r in _growth_pairs(eng)])
                    batch = grow_batch
                state = apply_delta(eng, state, batch)
                state, _ = eng.run(state, max_steps=steps)
            assert eng.layout.budget > b0, \
                "the growth batch was expected to expand the ghost slabs"
            assert float(jnp.max(state.prio)) <= tol
            assert eng._wire_backlog(state) == 0
            outs[name] = np.asarray(readback(eng, state).vertex_data[key])
        for name in ("int8", "bf16", "int8+ov", "f32+ov"):
            assert np.abs(outs[name] - outs["f32"]).max() <= 1e-5, name

    def test_lbp_deltas_across_regrow(self):
        """Same contract on LBP — edge messages, so the eref/eghost
        mirror path and the reverse (esend) wire are live — with the
        arrival batches regrowing both ghost slabs in place and deletion
        batches on either side.  wire_tol sits two decades under the
        tolerance: EF parks sub-wtol deltas, so remote priorities can
        rest ~10·wtol above the true residual and a wtol too close to
        tol stalls termination (measured, which is why the default
        resolve_tol is 0.1·tol, not 0.01·tol-tight workloads')."""
        prog, prefix_g, adds, key, tol, steps = _lbp_stream(60, 2)
        d1, d2 = _del_batches(prefix_g.structure, _cmd_vids(adds), 2)
        outs = {}
        for name, wire in (("f32", None),
                           ("int8", WireConfig(codec="int8", top_k=6,
                                               wire_tol=1e-8))):
            eng, state = make_dist_engine(
                prog, prefix_g, _mesh(4), tolerance=tol, slack=GROWY,
                wire=wire)
            state, _ = eng.run(state, max_steps=2500)
            b0, eb0 = eng.layout.budget, eng.layout.e_budget
            for batch in (d1, adds[0], adds[1], d2):
                state = apply_delta(eng, state, batch)
                state, _ = eng.run(state, max_steps=2500)
            assert eng.layout.budget > b0, "vertex slabs should regrow"
            assert eng.layout.e_budget > eb0, "edge slabs should regrow"
            assert float(jnp.max(state.prio)) <= tol
            assert eng._wire_backlog(state) == 0
            outs[name] = np.asarray(readback(eng, state).vertex_data[key])
        assert np.abs(outs["int8"] - outs["f32"]).max() <= 1e-5


# ---------------------------------------------------------------------------
# property: mirror-patch ≡ rebuild-from-scratch
# ---------------------------------------------------------------------------

@settings(max_examples=2, deadline=None)
@given(seed=st.integers(0, 10**6), machines=st.sampled_from([2, 4]),
       codec=st.sampled_from(["int8", "bf16"]))
def test_mirror_patch_matches_rebuild(seed, machines, codec):
    """After random delta batches (deletions + a forced regrow), the
    incrementally patched engine's fixed point matches an engine built
    from scratch on the final live graph under the same wire — the
    mirrors spliced batch-by-batch are as good as mirrors seeded whole."""
    # graph seed pinned to 1: it is the seed whose arrival batches leave
    # enough untouched prefix edges to delete from; the drawn seed still
    # varies the deletion plan, and machines/codec vary the wire shape
    prog, prefix_g, adds, key, tol, steps = _pr_stream(70, 1)
    d1, d2 = _del_batches(prefix_g.structure, _cmd_vids(adds), seed % 7)
    wire = WireConfig(codec=codec, top_k=6)
    eng, state = make_dist_engine(prog, prefix_g, _mesh(machines),
                                  tolerance=tol, slack=TINY, wire=wire)
    state, _ = eng.run(state, max_steps=steps)
    for batch in (d1, adds[0], adds[1], d2):
        eng, state, _ = apply_delta_growing(eng, state, batch)
        state, _ = eng.run(state, max_steps=steps)
    assert eng._wire_backlog(state) == 0
    final_g = readback(eng, state)
    eng2, state2 = make_dist_engine(prog, final_g, _mesh(machines),
                                    tolerance=tol, slack=TINY, wire=wire)
    state2, _ = eng2.run(state2, max_steps=steps)
    patched = np.asarray(readback(eng, state).vertex_data[key])
    rebuilt = np.asarray(readback(eng2, state2).vertex_data[key])
    assert np.abs(patched - rebuilt).max() <= 1e-5


# ---------------------------------------------------------------------------
# codec edge cases
# ---------------------------------------------------------------------------

class TestCodecEdgeCases:
    @settings(max_examples=8, deadline=None)
    @given(d=st.integers(1, 7), seed=st.integers(0, 10**6),
           codec=st.sampled_from(["int8", "bf16"]),
           scale=st.sampled_from([1e-38, 1e-20, 1.0, 3e38]))
    def test_round_trip_extremes(self, d, seed, codec, scale):
        """All-zero rows survive exactly; subnormal-magnitude rows (the
        int8 shared exponent clamps) and max-magnitude rows stay finite
        and within the clamped-scale error bound."""
        rng = np.random.default_rng(seed)
        x = (rng.uniform(-1, 1, size=(16, d)) * scale).astype(np.float32)
        x[0] = 0.0
        out = np.asarray(decode_payload(
            encode_payload({"v": jnp.asarray(x)}, codec), codec)["v"])
        assert np.isfinite(out).all()
        assert (out[0] == 0.0).all()
        if codec == "int8":
            # per-row power-of-two scale with the exponent clamped at
            # -126; subnormal inputs additionally flush to zero on CPU
            # XLA, so the absolute floor is the smallest normal
            bound = np.maximum(np.abs(x).max(axis=1, keepdims=True) / 127,
                               2.0 ** -126) + 1e-45
        else:
            # relative 2^-8, plus the bf16 subnormal/flush floor
            bound = np.abs(x) * 2.0 ** -8 + 2.0 ** -126
        assert (np.abs(out - x) <= bound).all()

    def test_nan_rows_decode_to_zero_not_garbage(self):
        """NaN containment: a poisoned row encodes as zeros (never NaN or
        junk on the receiver) and does not disturb its neighbours' rows —
        the property the dead-machine scenario leans on."""
        rng = np.random.default_rng(0)
        x = rng.normal(size=(8, 5)).astype(np.float32)
        bad = x.copy()
        bad[2] = np.nan
        bad[5, 3] = np.nan
        for codec in ("int8", "bf16"):
            out = np.asarray(decode_payload(
                encode_payload({"v": jnp.asarray(bad)}, codec), codec)["v"])
            ref = np.asarray(decode_payload(
                encode_payload({"v": jnp.asarray(x)}, codec), codec)["v"])
            assert np.isfinite(out).all()
            assert (out[2] == 0.0).all()
            assert out[5, 3] == 0.0
            # rows without NaN are encoded exactly as if the poison were
            # absent; the partially poisoned row keeps its finite lanes
            # (per-row scale ignores non-finite entries)
            keep = [0, 1, 3, 4, 6, 7]
            assert np.array_equal(out[keep], ref[keep])
            assert np.isfinite(out[5]).all()


# ---------------------------------------------------------------------------
# faults: dead machines and live migration under the quantized wire
# ---------------------------------------------------------------------------

def _pagerank(n, seed):
    st_ = connected_power_law_graph(n, seed=seed)
    return PageRankProgram(0.15, n), make_pagerank_graph(st_)


def _committed_cut(eng, state, mgr):
    state = eng.start_snapshot(state, (0,))
    while not eng.snapshot_complete(state):
        state = eng.step(state)
    save_snapshot(mgr, int(state.step_index), eng, state)
    return eng.clear_snapshot(state)


def _survivor_rows_finite(eng, state, dead):
    S = eng.layout.n_machines
    live = [m for m in range(S) if m != dead]
    for tree in (state.vown, state.vghost, state.edata, state.eghost):
        for leaf in jax.tree.leaves(tree):
            x = np.asarray(leaf)
            if not np.issubdtype(x.dtype, np.floating):
                continue
            x = x.reshape((S, x.shape[0] // S) + x.shape[1:])
            if not np.isfinite(x[live]).all():
                return False
    return True


class TestFaultsUnderQuantizedWire:
    def test_dead_machine_rows_never_reach_survivors(self):
        """mode="dead" NaN-poisons a shard and silences it.  Under the
        int8 wire the poison must stay contained: survivors keep stepping
        and no NaN ever decodes into a survivor's owned rows or caches."""
        prog, g = _pagerank(80, 3)
        eng = DistributedEngine(
            prog, g, _mesh(4), tolerance=1e-9, method="bfs",
            wire=WireConfig(codec="int8", top_k=6, wire_tol=7e-7))
        state = eng.init()
        for _ in range(3):
            state = eng.step(state)
        state = kill_machine(eng, state, 1, mode="dead")
        assert machine_data_lost(eng, state, 1)
        for _ in range(6):
            state = eng.step(state)
        assert machine_data_lost(eng, state, 1)  # poison stayed home
        assert _survivor_rows_finite(eng, state, dead=1)

    def test_migrate_leave_reconverges_with_wire(self, cpu_mesh, sub_mesh):
        """The migration audit fix: leave under a non-default wire
        re-seeds the mirrors from the restored cut and reschedules rows
        with pending residual, so the shrunken mesh still reaches the f32
        fixed point."""
        prog, g = _pagerank(80, 3)
        wire = WireConfig(codec="int8", top_k=6, wire_tol=7e-7)
        ref_eng = DistributedEngine(prog, g, cpu_mesh, tolerance=1e-9,
                                    method="bfs")
        rs, _ = ref_eng.run(ref_eng.init(), max_steps=3000)
        ref = np.asarray(ref_eng.vertex_data(rs)["rank"])

        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, async_writes=False)
            eng = DistributedEngine(prog, g, cpu_mesh, tolerance=1e-9,
                                    method="bfs", wire=wire)
            state = _committed_cut(eng, eng.step(eng.init()), mgr)
            state = eng.step(state)
            state = kill_machine(eng, state, 1, mode="dead")
            state = eng.step(eng.step(state))
            eng3, state3, info = migrate_leave(eng, state, 1,
                                               mesh=sub_mesh(3),
                                               manager=mgr)
        assert eng3.wire.codec == "int8"  # the wire survives the move
        assert info["lost_vertices"] > 0
        state3, _ = eng3.run(state3, max_steps=3000)
        assert float(jnp.max(state3.prio)) <= 1e-9
        assert eng3._wire_backlog(state3) == 0
        out = np.asarray(eng3.vertex_data(state3)["rank"])
        assert np.abs(out - ref).max() <= 1e-5

    def test_migrate_join_reconverges_with_wire(self, cpu_mesh, sub_mesh):
        prog, g = _pagerank(80, 3)
        wire = WireConfig(codec="int8", top_k=6, wire_tol=7e-7)
        eng = DistributedEngine(prog, g, sub_mesh(3), tolerance=1e-9,
                                method="bfs", wire=wire)
        state, _ = eng.run(eng.init(), max_steps=3000)
        out_before = np.asarray(eng.vertex_data(state)["rank"])
        eng4, state4, info = migrate_join(eng, state, mesh=cpu_mesh)
        assert eng4.layout.n_machines == 4
        assert eng4.wire.codec == "int8"
        state4, _ = eng4.run(state4, max_steps=3000)
        assert float(jnp.max(state4.prio)) <= 1e-9
        assert eng4._wire_backlog(state4) == 0
        out = np.asarray(eng4.vertex_data(state4)["rank"])
        assert np.abs(out - out_before).max() <= 1e-5


# ---------------------------------------------------------------------------
# rollback atomicity: slab growth succeeds, a later splice fails
# ---------------------------------------------------------------------------

def test_expansion_rollback_restores_host_and_device_tables():
    """A batch whose ghost-slab growth succeeds but whose later splice
    fails must apply not at all: the budgets, the host tables AND the
    device tables all come back to the pre-batch layout, and the engine
    keeps stepping — then the same growth prefix applies cleanly."""
    prog, prefix_g, adds, key, tol, steps = _pr_stream(72, 1)
    eng, state = make_dist_engine(
        prog, prefix_g, _mesh(4), tolerance=tol, slack=GROWY,
        wire=WireConfig(codec="int8", top_k=6))
    state, _ = eng.run(state, max_steps=steps)
    # one benign batch so the patcher (and its slab maps) exist
    state = apply_delta(eng, state, adds[0])
    state, _ = eng.run(state, max_steps=steps)
    lay = eng.layout
    b0 = lay.budget
    host_before = {k: v.copy() for k, v in lay.tables.items()}
    dev_before = {k: np.asarray(v).copy() for k, v in eng._tables.items()}
    wire_before = jax.tree.map(lambda x: np.asarray(x).copy(), state.wire)
    # growth edges, then a poison pill: re-adding an existing edge raises
    grow = _growth_pairs(eng)
    dup = (int(prefix_g.structure.senders[0]),
           int(prefix_g.structure.receivers[0]))
    poisoned = DeltaBatch([AddEdge(s, r) for s, r in grow]
                          + [AddEdge(*dup)])
    with pytest.raises(ValueError):
        apply_delta(eng, state, poisoned)
    assert lay.budget == b0
    assert eng._stream_patcher.B == b0
    for k, v in lay.tables.items():
        assert np.array_equal(v, host_before[k]), k
        assert np.array_equal(np.asarray(eng._tables[k]),
                              dev_before[k]), f"device {k}"
    # state (including the wire mirrors) was never replaced
    for a, b in zip(jax.tree.leaves(wire_before),
                    jax.tree.leaves(state.wire)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    # the same growth prefix without the poison applies and expands
    state = apply_delta(eng, state, DeltaBatch(
        [AddEdge(s, r) for s, r in grow]))
    assert lay.budget > b0
    state, _ = eng.run(state, max_steps=steps)
    assert float(jnp.max(state.prio)) <= tol
    assert eng._wire_backlog(state) == 0


# ---------------------------------------------------------------------------
# overlap: the jaxpr schedule audit
# ---------------------------------------------------------------------------

def test_locking_engine_rejects_overlap():
    """Single-phase engines have no next phase to defer a packet into;
    the knob must fail loudly, not silently run sequential."""
    from repro.dist.locking import DistributedLockingEngine
    prog, g = _pagerank(40, 0)
    with pytest.raises(ValueError, match="overlap"):
        DistributedLockingEngine(prog, g, _mesh(4), tolerance=1e-8,
                                 overlap=True)


def test_overlap_issues_collective_before_independent_gather():
    """The §3.14 schedule assertion, at the jaxpr level: compared to the
    sequential build (same collectives), the double-buffered build issues
    strictly more collectives ahead of gathers that do not consume them —
    and strictly fewer gathers that block on the in-flight exchange."""
    prog, g = _pagerank(60, 0)
    reps = {}
    for wire_name, wire in (("f32", None),
                            ("int8", WireConfig(codec="int8", top_k=4))):
        for ov in (False, True):
            eng = DistributedEngine(prog, g, _mesh(4), tolerance=1e-8,
                                    wire=wire, overlap=ov, use_fused=False)
            reps[(wire_name, ov)] = exchange_overlap_report(eng)
    for wire_name in ("f32", "int8"):
        seq = reps[(wire_name, False)]
        ovl = reps[(wire_name, True)]
        assert seq["all_to_all"] == ovl["all_to_all"] > 0
        assert ovl["independent_gathers"] > seq["independent_gathers"]
        assert ovl["dependent_gathers"] < seq["dependent_gathers"]
