"""The autonomous control loop (obs/supervisor.py, DESIGN §3.15 layer 3).

ROADMAP item 1's leftover was that the Watchdog/StragglerMonitor only
*detected* failures — remediation (``migrate_leave``/``migrate_join``/
``shed_atoms``/``steal_backlog``) was host-harness choreography.  These
tests close the loop: a ``Supervisor`` handed to ``run()`` consumes the
live beat/backlog stream and fires the remedies itself, with ZERO
migration or steal calls in the test body — every action here is read
back out of ``supervisor.actions`` and the ObsSession event log, which
is the acceptance shape the churn benchmark asserts too.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps.pagerank import PageRankProgram, make_pagerank_graph
from repro.checkpoint.manager import CheckpointManager
from repro.core import Engine
from repro.core.graph import GraphStructure
from repro.dist.balance import (StragglerMonitor, WorkStealingScheduler,
                                stolen_updates)
from repro.dist.engine import DistributedEngine
from repro.dist.faults import kill_machine, resume_machine
from repro.graphs.generators import connected_power_law_graph as \
    connected_graph
from repro.obs import ObsConfig, ObsSession, Supervisor

needs_mesh = pytest.mark.skipif(
    jax.device_count() < 4, reason="needs 4 forced host devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")


def _pagerank_case(n=80, seed=3):
    g = make_pagerank_graph(connected_graph(n, seed=seed))
    return g, PageRankProgram(0.15, n), "rank", 1e-9


def _make(prog, g, mesh, tol):
    return DistributedEngine(prog, g, mesh, tolerance=tol, method="bfs")


def _session():
    return ObsSession(ObsConfig(enabled=True, timeline=True))


def _kinds(sup):
    return [a["kind"] for a in sup.actions]


# ---------------------------------------------------------------------------
# death: watchdog escalation -> migrate_leave, all inside run()
# ---------------------------------------------------------------------------

@needs_mesh
class TestDeathHealing:
    def test_dead_machine_healed_inside_run(self, cpu_mesh, sub_mesh,
                                            tmp_path):
        """A mode="dead" loss mid-run: the supervisor owns the snapshot
        cadence, declares the machine dead from frozen beats, rebuilds
        the mesh at S-1 from its own committed cut, and the run
        reconverges — the host never calls a migrate_* function."""
        g, prog, key, tol = _pagerank_case()
        ref_eng = _make(prog, g, cpu_mesh, tol)
        rs, _ = ref_eng.run(ref_eng.init(), max_steps=3000)
        ref = ref_eng.vertex_data(rs)[key]

        eng = _make(prog, g, cpu_mesh, tol)
        ses = _session()
        sup = Supervisor(manager=CheckpointManager(str(tmp_path)),
                         mesh_factory=sub_mesh, session=ses,
                         suspect_after=2, dead_after=4, snapshot_every=3)
        state, _ = eng.run(eng.init(), max_steps=14, supervisor=sup)
        eng = sup.engine
        assert sup.cuts_committed >= 1, \
            "supervisor must commit a cut before the fault"

        state = kill_machine(eng, state, 2, mode="dead")
        final, _ = eng.run(state, max_steps=3000, supervisor=sup)
        eng = sup.engine

        kinds = _kinds(sup)
        assert "watchdog_dead" in kinds
        assert "migrate_leave" in kinds
        leave = next(a for a in sup.actions if a["kind"] == "migrate_leave")
        assert leave["machine"] == 2
        assert eng.layout.n_machines == 3
        assert float(jnp.max(final.prio)) <= tol
        out = eng.vertex_data(final)[key]
        assert np.abs(out - ref).max() <= 1e-5

        # remediation is auditable from the session: structured event +
        # a timeline span on the supervisor track
        assert any(e["kind"] == "migrate_leave" for e in ses.events)
        spans = [e for e in ses.timeline.events
                 if e.get("ph") == "X" and e["name"] == "migrate_leave"]
        assert spans and spans[0]["args"]["machine"] == 2

    def test_dead_without_manager_is_reported_not_hidden(self, cpu_mesh):
        g, prog, _, tol = _pagerank_case(n=40)
        eng = _make(prog, g, cpu_mesh, tol)
        sup = Supervisor(suspect_after=1, dead_after=2)
        state, _ = eng.run(eng.init(), max_steps=4, supervisor=sup)
        state = kill_machine(eng, state, 1, mode="stall")
        eng.run(state, max_steps=8, supervisor=sup)
        kinds = _kinds(sup)
        assert "dead_unremediated" in kinds
        # reported exactly once, not every tick
        assert kinds.count("dead_unremediated") == 1


# ---------------------------------------------------------------------------
# straggler: flagged from beats alone, shed, reinstated on recovery
# (satellite: StragglerMonitor regression)
# ---------------------------------------------------------------------------

@needs_mesh
class TestStragglerLoop:
    def test_stall_flagged_shed_and_reinstated(self, cpu_mesh):
        """kill_machine(mode="stall") — data intact, beats frozen.  The
        supervisor must flag the straggler within K steps from beats
        alone, shed its backlog (data is intact so the data-lost guard
        passes), and on resume_machine reinstate it without a spurious
        steal; the run still reaches the uninterrupted fixed point."""
        K = 10
        g, prog, key, tol = _pagerank_case()
        ref_eng = _make(prog, g, cpu_mesh, tol)
        rs, _ = ref_eng.run(ref_eng.init(), max_steps=3000)
        ref = ref_eng.vertex_data(rs)[key]

        eng = _make(prog, g, cpu_mesh, tol)
        ses = _session()
        # dead_after high: the watchdog may suspect but must not declare
        # death — this scenario belongs to the straggler path
        sup = Supervisor(session=ses, suspect_after=2, dead_after=50,
                         straggler_skew=3, straggler_patience=2,
                         shed_frac=1.0)
        state, _ = eng.run(eng.init(), max_steps=4, supervisor=sup)
        eng = sup.engine
        tick0 = sup.ticks

        state = kill_machine(eng, state, 1, mode="stall")
        state, _ = eng.run(state, max_steps=K, supervisor=sup)
        eng = sup.engine
        flags = [a for a in sup.actions if a["kind"] == "straggler"]
        assert flags and flags[0]["machine"] == 1
        assert flags[0]["tick"] - tick0 <= K, \
            "straggler must be flagged within K steps from beats alone"
        sheds = [a for a in sup.actions if a["kind"] == "shed_atoms"]
        assert sheds and sheds[0]["machine"] == 1
        assert sheds[0]["shed_atoms"] > 0

        resume_machine(eng, 1)
        final, _ = eng.run(state, max_steps=3000, supervisor=sup)
        eng = sup.engine
        kinds = _kinds(sup)
        assert "recovered" in kinds, "beat progress must clear the flag"
        assert "watchdog_reinstated" in kinds
        assert "steal_backlog" not in kinds, "no spurious steal"
        assert "migrate_leave" not in kinds
        assert float(jnp.max(final.prio)) <= tol
        out = eng.vertex_data(final)[key]
        assert np.abs(out - ref).max() <= 1e-5

    def test_data_lost_straggler_is_not_shed(self, cpu_mesh):
        """mode="dead" looks like a straggler (silent beats) before the
        watchdog escalates — shedding would move NaN-poisoned rows onto
        survivors, so the supervisor must skip the shed and let the
        watchdog own the machine."""
        g, prog, _, tol = _pagerank_case(n=40)
        eng = _make(prog, g, cpu_mesh, tol)
        # straggler fires well before death is declared
        sup = Supervisor(suspect_after=2, dead_after=40,
                         straggler_skew=2, straggler_patience=1)
        state, _ = eng.run(eng.init(), max_steps=4, supervisor=sup)
        state = kill_machine(sup.engine, state, 2, mode="dead")
        sup.engine.run(state, max_steps=10, supervisor=sup)
        kinds = _kinds(sup)
        assert "shed_skipped_data_lost" in kinds
        assert "shed_atoms" not in kinds


class TestStragglerMonitorObserve:
    """Unit shape of the stateful detector: beats are cumulative, so a
    recovered machine stays behind in absolute skew forever — progress,
    not position, clears the flag."""

    def test_flags_frozen_laggard_then_recovers_on_progress(self):
        mon = StragglerMonitor(4, skew=4, patience=2)
        assert mon.observe([10, 10, 10, 10]) == []  # baseline
        assert mon.observe([12, 12, 10, 12]) == []  # lag 2 < skew
        assert mon.observe([14, 14, 10, 14]) == []  # streak 1 < patience
        assert mon.observe([16, 16, 10, 16]) == [("straggler", 2)]
        assert mon.observe([18, 18, 10, 18]) == []  # flagged is sticky
        # one beat of progress clears it despite absolute lag of 9
        assert mon.observe([20, 20, 11, 20]) == [("recovered", 2)]

    def test_uniformly_slow_mesh_never_flags(self):
        mon = StragglerMonitor(3, skew=2, patience=1)
        beats = np.zeros(3, np.int64)
        for _ in range(6):
            beats = beats + 1
            assert mon.observe(beats) == []

    def test_exclude_masks_watchdog_owned_machines(self):
        mon = StragglerMonitor(2, skew=1, patience=1)
        mon.observe([5, 5])
        assert mon.observe([9, 5], exclude=(1,)) == []
        assert mon.observe([13, 5]) == [("straggler", 1)]


# ---------------------------------------------------------------------------
# join: offered hardware lands inside run()
# ---------------------------------------------------------------------------

@needs_mesh
class TestJoin:
    def test_offered_machine_joins_inside_run(self, cpu_mesh, sub_mesh):
        g, prog, key, tol = _pagerank_case()
        ref_eng = _make(prog, g, cpu_mesh, tol)
        rs, _ = ref_eng.run(ref_eng.init(), max_steps=3000)
        ref = ref_eng.vertex_data(rs)[key]

        eng = _make(prog, g, sub_mesh(3), tol)
        ses = _session()
        sup = Supervisor(session=ses)
        sup.offer_machine(cpu_mesh)
        assert sup.pending_work(), "an offered machine is owed work"
        final, _ = eng.run(eng.init(), max_steps=3000, supervisor=sup)
        eng = sup.engine

        assert eng.layout.n_machines == 4
        joins = [a for a in sup.actions if a["kind"] == "migrate_join"]
        assert joins and joins[0]["joined_machine"] == 3
        assert not sup.pending_work()
        assert float(jnp.max(final.prio)) <= tol
        out = eng.vertex_data(final)[key]
        assert np.abs(out - ref).max() <= 1e-5
        assert any(e["kind"] == "offer_machine" for e in ses.events)


# ---------------------------------------------------------------------------
# local path: progress-skew fires steal_backlog mid-run, zero retrace
# ---------------------------------------------------------------------------

class TestLocalSteal:
    def test_supervisor_fires_steal_backlog_mid_run(self):
        """Queues 1-3 own only isolated vertices (converged after one
        update, never rescheduled) while queue 0 owns a 50-ring: the
        supervisor sees idle queues next to a starved victim and fires
        ``steal_backlog`` itself — a scheduler value update, no retrace —
        and the stolen vertices execute (``stolen_updates > 0``)."""
        n, tol = 60, 1e-7
        ring = np.arange(50)
        st_, _ = GraphStructure.undirected(ring, (ring + 1) % 50, n)
        g = make_pagerank_graph(st_)
        prog = PageRankProgram(0.15, n)

        ref_eng = Engine(prog, g, tolerance=tol)
        ref_state, _ = ref_eng.run(ref_eng.init(g), max_steps=3000)
        ref = np.asarray(ref_state.graph.vertex_data["rank"])

        machine_of = np.zeros(n, np.int32)
        machine_of[50:] = 1 + np.arange(10) % 3
        ws = WorkStealingScheduler(prog, st_, tol, machine_of,
                                   pipeline_length=4)
        eng = Engine(prog, g, tolerance=tol, scheduler=ws)
        ses = _session()
        sup = Supervisor(session=ses, steal_skew=2, steal_frac=0.8)
        state, _ = eng.run(eng.init(g), max_steps=3000, supervisor=sup)

        steals = [a for a in sup.actions if a["kind"] == "steal_backlog"]
        assert steals, "supervisor never fired steal_backlog"
        assert steals[0]["victim"] == 0
        assert set(steals[0]["to"]) <= {1, 2, 3}
        assert steals[0]["moved"] > 0
        assert stolen_updates(state.sched) > 0, \
            "stolen vertices must actually execute"
        out = np.asarray(state.graph.vertex_data["rank"])
        assert np.abs(out - ref).max() <= 1e-5
        assert any(e["kind"] == "steal_backlog" for e in ses.events)

    def test_balanced_queues_never_steal(self):
        g, prog, _, _ = _pagerank_case(n=40)
        st_ = g.structure
        machine_of = np.arange(st_.n_vertices) % 4
        ws = WorkStealingScheduler(prog, st_, 1e-6, machine_of,
                                   pipeline_length=8)
        eng = Engine(prog, g, tolerance=1e-6, scheduler=ws)
        sup = Supervisor(steal_skew=2)
        eng.run(eng.init(g), max_steps=200, supervisor=sup)
        assert "steal_backlog" not in _kinds(sup)
