"""End-to-end behaviour tests for the GraphLab core + paper applications."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps.als import ALSProgram, als_rmse, make_als_graph
from repro.apps.coem import CoEMProgram, coem_accuracy, make_coem_graph
from repro.apps.lbp import (LoopyBPProgram, exact_marginals_chain,
                            make_mrf_graph)
from repro.apps.pagerank import (PageRankProgram, exact_pagerank,
                                 make_pagerank_graph)
from repro.core import (BSPEngine, ChromaticEngine, Consistency,
                        DynamicEngine)
from repro.core.graph import GraphStructure
from repro.graphs.generators import (bipartite_graph, cora_like,
                                     grid3d_graph, power_law_graph)

TOL = 1e-7


@pytest.fixture(scope="module")
def web_graph():
    return power_law_graph(300, avg_degree=6, seed=1)


class TestPageRank:
    def test_chromatic_converges_to_exact(self, web_graph):
        g = make_pagerank_graph(web_graph)
        prog = PageRankProgram(0.15, web_graph.n_vertices)
        eng = ChromaticEngine(prog, g, tolerance=TOL)
        s, _ = eng.run(eng.init(g), max_steps=300)
        exact = exact_pagerank(web_graph, 0.15, 500)
        assert np.abs(np.asarray(s.graph.vertex_data["rank"])
                      - exact).sum() < 1e-4

    def test_all_engines_agree(self, web_graph):
        g = make_pagerank_graph(web_graph)
        prog = PageRankProgram(0.15, web_graph.n_vertices)
        results = []
        for eng in (BSPEngine(prog, g, tolerance=TOL),
                    ChromaticEngine(prog, g, tolerance=TOL),
                    DynamicEngine(prog, g, pipeline_length=64,
                                  tolerance=TOL)):
            s, _ = eng.run(eng.init(g), max_steps=5000)
            results.append(np.asarray(s.graph.vertex_data["rank"]))
        np.testing.assert_allclose(results[0], results[1], atol=1e-5)
        np.testing.assert_allclose(results[0], results[2], atol=1e-5)

    def test_async_beats_sync_on_updates(self, web_graph):
        """Paper Fig. 1(a): chromatic (Gauss-Seidel) needs fewer updates
        than BSP (Jacobi) for the same accuracy."""
        g = make_pagerank_graph(web_graph)
        prog = PageRankProgram(0.15, web_graph.n_vertices)
        bsp = BSPEngine(prog, g, tolerance=TOL)
        sb, _ = bsp.run(bsp.init(g), max_steps=1000)
        chrom = ChromaticEngine(prog, g, tolerance=TOL)
        sc, _ = chrom.run(chrom.init(g), max_steps=1000)
        assert int(sc.total_updates) < int(sb.total_updates)

    def test_update_count_skew(self, web_graph):
        """Paper Fig. 1(b): dynamic scheduling leaves most vertices with
        near-minimal update counts."""
        g = make_pagerank_graph(web_graph)
        prog = PageRankProgram(0.15, web_graph.n_vertices)
        eng = DynamicEngine(prog, g, pipeline_length=32, tolerance=1e-5)
        s, _ = eng.run(eng.init(g), max_steps=20000)
        counts = np.asarray(s.update_count)
        assert counts.max() > counts.min()  # non-uniform
        # the heavy tail is small
        assert (counts > np.median(counts) * 3).mean() < 0.2


class TestALS:
    def test_train_rmse_drops(self):
        g, _ = make_als_graph(80, 60, 2500, d=4, seed=0, noise=0.05)
        prog = ALSProgram(d=4)
        eng = ChromaticEngine(prog, g, tolerance=1e-3)
        before = als_rmse(g, train=True)
        s, _ = eng.run(eng.init(g), max_steps=15)
        after = als_rmse(s.graph, train=True)
        assert after < before * 0.5

    def test_bipartite_two_coloring_used(self):
        g, _ = make_als_graph(40, 30, 600, d=3, seed=1)
        eng = ChromaticEngine(ALSProgram(d=3), g)
        assert eng.num_colors == 2  # paper: ALS graph is 2-colorable

    def test_racing_less_stable_than_serializable(self):
        """Paper Fig. 1(d): non-serializable dynamic ALS oscillates."""
        g, _ = make_als_graph(60, 50, 1800, d=6, seed=3, noise=0.02)
        swings = {}
        for ser in (True, False):
            prog = ALSProgram(d=6, reg=0.01)
            eng = DynamicEngine(prog, g, pipeline_length=110,
                                serializable=ser, tolerance=1e-4)
            s = eng.init(g)
            rmses = []
            for _ in range(40):
                s = eng.step(s)
                rmses.append(als_rmse(s.graph, train=True))
            swings[ser] = float(np.abs(np.diff(rmses)).sum())
        assert swings[False] > swings[True]


class TestLBP:
    def test_chain_marginals_exact(self):
        """On a tree (chain), LBP is exact — compare to brute force."""
        n, k = 6, 3
        st, _ = GraphStructure.undirected(np.arange(n - 1),
                                          np.arange(1, n), n)
        g = make_mrf_graph(st, n_states=k, seed=0)
        prog = LoopyBPProgram(k, smoothing=0.7)
        eng = ChromaticEngine(prog, g, tolerance=1e-9)
        s, _ = eng.run(eng.init(g), max_steps=100)
        beliefs = np.exp(np.asarray(s.graph.vertex_data["belief"]))
        beliefs /= beliefs.sum(1, keepdims=True)
        exact = exact_marginals_chain(
            np.asarray(g.vertex_data["unary"]), prog.pairwise)
        np.testing.assert_allclose(beliefs, exact, atol=1e-4)

    def test_grid_converges(self):
        st = grid3d_graph(4, 4, 4, connectivity=26)
        g = make_mrf_graph(st, n_states=2, seed=1)
        prog = LoopyBPProgram(2, smoothing=0.5)
        eng = DynamicEngine(prog, g, pipeline_length=32, tolerance=1e-4)
        s, _ = eng.run(eng.init(g), max_steps=3000)
        assert float(jnp.max(s.prio)) <= 1e-4  # scheduler drained
        assert not bool(jnp.isnan(s.graph.vertex_data["belief"]).any())


class TestCoEM:
    def test_accuracy_beats_chance(self):
        g, info = make_coem_graph(400, 120, 5000, n_types=4, seed=0)
        prog = CoEMProgram(4)
        eng = ChromaticEngine(prog, g, tolerance=1e-4)
        s, _ = eng.run(eng.init(g), max_steps=30)
        acc = coem_accuracy(s.graph, info)
        assert acc > 0.5  # chance = 0.25

    def test_seeds_never_change(self):
        g, info = make_coem_graph(200, 60, 2000, n_types=3, seed=1)
        seeds_before = np.asarray(g.vertex_data["p"]).copy()
        seed_mask = np.asarray(g.vertex_data["seed"]) > 0.5
        prog = CoEMProgram(3)
        eng = ChromaticEngine(prog, g, tolerance=1e-4)
        s, _ = eng.run(eng.init(g), max_steps=10)
        after = np.asarray(s.graph.vertex_data["p"])
        np.testing.assert_allclose(after[seed_mask],
                                   seeds_before[seed_mask])
