"""Quantized + top-k ghost wire (ISSUE 8; DESIGN.md §3.14).

Codec-level: per-row int8/bf16 round-trip error bounds, byte accounting,
lossless rank narrowing.  Protocol-level, via hypothesis sweeps over random
graphs × 2/4-machine meshes: the versioning invariant survives the top-k
wire — each (vertex, cacher) pair receives at most one row per phase — and
deferral is never a drop: after convergence the wire backlog is zero and
every ghost cache row matches its owner row to the staleness contract's
bound, including rows whose deltas lost top-k elections along the way.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.dist.wire import (RANK_INF, QRows, WireConfig, decode_payload,
                             decode_rank, encode_payload, encode_rank,
                             payload_row_nbytes, rank_codec_fits)

needs4 = pytest.mark.skipif(
    jax.device_count() < 4, reason="needs 4 forced host devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")


# ---------------------------------------------------------------------------
# codecs
# ---------------------------------------------------------------------------

class TestRowCodecs:
    @settings(max_examples=10, deadline=None)
    @given(rows=st.integers(1, 64), d=st.integers(1, 9),
           seed=st.integers(0, 10**6), scale=st.sampled_from(
               [1.0, 1e-6, 1e6]))
    def test_int8_roundtrip_bound(self, rows, d, seed, scale):
        rng = np.random.default_rng(seed)
        x = (rng.normal(size=(rows, d)) * scale).astype(np.float32)
        x[0] = 0.0  # zero rows must survive exactly (no spurious deltas)
        tree = {"v": jnp.asarray(x)}
        out = np.asarray(decode_payload(encode_payload(tree, "int8"),
                                        "int8")["v"])
        # per-row power-of-two scale: |err| <= rowmax / 127 per component
        bound = np.abs(x).max(axis=1, keepdims=True) / 127 + 1e-30
        assert (np.abs(out - x) <= bound).all()
        assert (out[0] == 0.0).all()

    def test_bf16_roundtrip_bound(self):
        rng = np.random.default_rng(0)
        x = rng.normal(size=(32, 5)).astype(np.float32)
        out = np.asarray(decode_payload(encode_payload({"v": jnp.asarray(x)},
                                                       "bf16"), "bf16")["v"])
        assert (np.abs(out - x) <= np.abs(x) * 2.0**-8 + 1e-30).all()

    def test_f32_is_identity(self):
        x = jnp.arange(12.0, dtype=jnp.float32).reshape(3, 4)
        enc = encode_payload({"v": x}, "f32")
        assert enc["v"] is x

    def test_int8_wire_is_int8_leaves(self):
        enc = encode_payload({"v": jnp.ones((4, 3), jnp.float32)}, "int8")
        assert isinstance(enc["v"], QRows)
        assert enc["v"].q.dtype == jnp.int8 and enc["v"].e.dtype == jnp.int8
        # per row: 3 int8 mantissas + 1 int8 shared exponent
        assert payload_row_nbytes(enc) == 4

    def test_payload_row_nbytes(self):
        f32 = {"a": jnp.zeros((5, 3), jnp.float32),
               "b": jnp.zeros((5,), jnp.float32)}
        assert payload_row_nbytes(f32) == 16
        assert payload_row_nbytes(encode_payload(f32, "bf16")) == 8
        assert payload_row_nbytes(encode_payload(f32, "int8")) == 6


class TestRankCodec:
    def test_lossless_including_inf(self):
        vals = np.array([0, 1, 7, 500, int(RANK_INF) - 1, np.inf],
                        np.float32)
        q = encode_rank(jnp.asarray(vals))
        assert q.dtype == jnp.int16
        out = np.asarray(decode_rank(q))
        assert (out[:-1] == vals[:-1]).all() and np.isinf(out[-1])

    def test_fits_guard(self):
        assert rank_codec_fits(1000)
        assert not rank_codec_fits(int(RANK_INF) + 5)


def test_wire_config_validation():
    with pytest.raises(ValueError):
        WireConfig(codec="fp4")
    assert WireConfig().is_default
    assert not WireConfig(codec="int8").is_default
    assert not WireConfig(codec="int8", error_feedback=False).uses_delta
    assert WireConfig(codec="int8").resolve_tol(1e-3) == pytest.approx(1e-4)
    assert WireConfig(wire_tol=7e-7).resolve_tol(1e-3) == 7e-7


# ---------------------------------------------------------------------------
# protocol, on the real engines
# ---------------------------------------------------------------------------

def _mesh(n):
    devs = np.asarray(jax.devices()[:n]).reshape(n, 1)
    return jax.sharding.Mesh(devs, ("data", "model"))


def _pagerank(n, seed):
    from repro.apps.pagerank import PageRankProgram, make_pagerank_graph
    from repro.graphs.generators import connected_power_law_graph
    st_ = connected_power_law_graph(n, seed=seed)
    return PageRankProgram(0.15, n), make_pagerank_graph(st_)


def _ghost_cache_err(eng, state):
    """max |ghost row − owner row| over every populated (vertex, cacher)
    vertex-cache slot — the eventual-delivery measure.  Slot layout:
    machine d's ghost slot (owner, b) holds the row owner sends in its
    block for d: send_idx[owner·S·B + d·B + b]."""
    lay = eng.layout
    S, B, n_loc = lay.n_machines, lay.budget, lay.n_loc
    sm = np.asarray(lay.tables["send_mask"]).astype(bool)
    si = np.asarray(lay.tables["send_idx"])
    ent = np.nonzero(sm)[0]
    owner = ent // (S * B)
    dest = (ent % (S * B)) // B
    slot = dest * (S * B) + owner * B + (ent % B)
    row = owner * n_loc + si[ent]
    errs = [0.0]
    for go, gh in zip(jax.tree.leaves(state.vown),
                      jax.tree.leaves(state.vghost)):
        errs.append(float(np.abs(np.asarray(gh)[slot]
                                 - np.asarray(go)[row]).max()))
    return max(errs)


@needs4
class TestWireProtocol:
    @settings(max_examples=4, deadline=None)
    @given(n=st.integers(40, 120), seed=st.integers(0, 10**6),
           machines=st.sampled_from([2, 4]),
           codec=st.sampled_from(["int8", "bf16"]))
    def test_versioning_and_eventual_delivery(self, n, seed, machines,
                                              codec):
        from repro.dist.engine import DistributedEngine
        prog, g = _pagerank(n, seed)
        wtol = 1e-6
        eng = DistributedEngine(
            prog, g, _mesh(machines), tolerance=1e-8,
            wire=WireConfig(codec=codec, top_k=4, wire_tol=wtol))
        state = eng.init()
        slots = int(np.asarray(eng.layout.tables["send_mask"]).sum())
        phases = eng.num_colors
        prev = 0
        for _ in range(3000):
            if (float(jnp.max(state.prio)) <= eng.tolerance
                    and eng._wire_backlog(state) == 0):
                break
            state = eng.step(state)
            rows = int(jnp.sum(state.traffic_v))
            # versioning invariant on the top-k wire: each (vertex, cacher)
            # receives at most one row per phase
            assert rows - prev <= slots * phases
            prev = rows
        # deferral is never a drop: backlog drained and every cache row —
        # including top-k election losers along the way — caught up to its
        # owner within the staleness contract (undelivered residual < wtol
        # per row; a small multiple covers accumulation across leaves)
        assert eng._wire_backlog(state) == 0
        assert float(jnp.max(state.prio)) <= eng.tolerance
        assert _ghost_cache_err(eng, state) <= 8 * wtol

    def test_quantized_matches_f32_fixed_point(self):
        from repro.dist.engine import DistributedEngine
        prog, g = _pagerank(80, 3)
        outs = {}
        for name, wire in [
                ("f32", None),
                ("int8", WireConfig(codec="int8", top_k=6, wire_tol=7e-7))]:
            eng = DistributedEngine(prog, g, _mesh(4), tolerance=1e-9,
                                    method="bfs", wire=wire)
            s, _ = eng.run(eng.init(), max_steps=600)
            outs[name] = np.asarray(eng.vertex_data(s)["rank"])
        assert np.abs(outs["int8"] - outs["f32"]).max() <= 1e-5

    def test_error_feedback_beats_absolute(self):
        # the ablation: same codec, no mirrors/error feedback — the
        # quantization error never drains and the fixed point is wrong at
        # the codec's resolution
        from repro.dist.engine import DistributedEngine
        prog, g = _pagerank(80, 3)
        errs = {}
        ref = None
        for name, wire in [
                ("f32", None),
                ("ef", WireConfig(codec="int8", top_k=6, wire_tol=7e-7)),
                ("abs", WireConfig(codec="int8", error_feedback=False))]:
            eng = DistributedEngine(prog, g, _mesh(4), tolerance=1e-9,
                                    method="bfs", wire=wire)
            s, _ = eng.run(eng.init(), max_steps=600)
            out = np.asarray(eng.vertex_data(s)["rank"])
            if ref is None:
                ref = out
            errs[name] = np.abs(out - ref).max()
        assert errs["ef"] <= 1e-5
        assert errs["abs"] > 10 * errs["ef"]

    def test_byte_counters_match_row_payload(self):
        from repro.dist.engine import DistributedEngine
        from repro.dist.wire import payload_row_nbytes
        prog, g = _pagerank(60, 1)
        for wire, per_row in [
                (None, None),  # f32 PageRank row: rank + deg = 8 bytes
                (WireConfig(codec="int8", top_k=6, wire_tol=7e-7), None)]:
            eng = DistributedEngine(prog, g, _mesh(4), tolerance=1e-8,
                                    wire=wire)
            s, _ = eng.run(eng.init(), max_steps=400)
            rows = eng.ghost_rows_sent(s)
            assert rows > 0
            nbytes = eng.ghost_bytes_sent(s)
            assert nbytes % rows == 0
            if wire is None:
                assert nbytes // rows == 8
            else:
                # delta + contrib + acc sub-payloads, all int8-encoded:
                # static per-row size, so bytes divide rows exactly
                assert nbytes // rows < 8

    def test_locking_rank_wire_narrows_losslessly(self):
        from repro.dist.locking import DistributedLockingEngine
        prog, g = _pagerank(60, 2)
        outs, ranks = {}, {}
        for name, wire in [
                ("f32", None),
                ("int8", WireConfig(codec="int8", top_k=6, wire_tol=7e-7))]:
            eng = DistributedLockingEngine(prog, g, _mesh(4),
                                           tolerance=1e-8, wire=wire)
            s, _ = eng.run(eng.init(), max_steps=2000)
            outs[name] = np.asarray(eng.vertex_data(s)["rank"])
            ranks[name] = (eng.rank_rows_sent(s), eng.rank_bytes_sent(s))
        assert np.abs(outs["int8"] - outs["f32"]).max() <= 1e-5
        # f32 ranks: 4 bytes/row; narrowed wire: 2 bytes/row
        rows_f32, bytes_f32 = ranks["f32"]
        rows_q, bytes_q = ranks["int8"]
        assert rows_f32 > 0 and bytes_f32 == 4 * rows_f32
        assert rows_q > 0 and bytes_q == 2 * rows_q


@needs4
def test_streaming_accepts_quantized_wire():
    # the PR-8 construction gate is gone: stream/ingest.py patches the
    # error-feedback mirrors in lockstep with every splice (DESIGN §3.14),
    # so quantized wire on streaming engines is fully supported — deep
    # equivalence coverage lives in tests/test_stream_wire.py
    from repro.dist.engine import DistributedEngine
    from repro.stream import make_dist_engine
    prog, g = _pagerank(60, 0)
    eng, sg = make_dist_engine(prog, g, _mesh(4), engine_cls=DistributedEngine,
                               tolerance=1e-6,
                               wire=WireConfig(codec="int8", top_k=4))
    state, _ = eng.run(eng.init(), max_steps=500)
    assert float(np.max(state.prio)) <= 1e-6


# ---------------------------------------------------------------------------
# traffic accounting under overlap (obs satellite; DESIGN §3.14/§3.15)
# ---------------------------------------------------------------------------

def _cachers_per_row(eng):
    """[S*n_loc] i64: how many remote caches each own row feeds — the
    number of send-table slots sourcing it."""
    lay = eng.layout
    S, B, n_loc = lay.n_machines, lay.budget, lay.n_loc
    sm = np.asarray(lay.tables["send_mask"]).astype(bool)
    si = np.asarray(lay.tables["send_idx"])
    ent = np.nonzero(sm)[0]
    row = (ent // (S * B)) * n_loc + si[ent]
    return np.bincount(row, minlength=S * n_loc)


def _vertex_traffic_oracle(eng, state):
    """Exact row count the f32 wire must report: every executed update
    ships its row to each of its cachers exactly once — deferred packets
    are counted at issue, the last color never defers (no trailing-flush
    double count), and marker rows ride the snapshot channel, never
    ``traffic_v``."""
    uc = np.asarray(jax.device_get(state.update_count), np.int64)
    return int((uc * _cachers_per_row(eng)).sum())


@needs4
class TestOverlapTrafficOracle:
    @pytest.mark.parametrize("overlap", [False, True],
                             ids=["in-phase", "overlap"])
    def test_rows_counted_exactly_once(self, overlap):
        from repro.dist.engine import DistributedEngine
        prog, g = _pagerank(80, 3)
        eng = DistributedEngine(prog, g, _mesh(4), tolerance=1e-8,
                                method="bfs", overlap=overlap)
        state, _ = eng.run(eng.init(), max_steps=600)
        assert float(jnp.max(state.prio)) <= 1e-8
        rows = int(np.asarray(state.traffic_v).sum())
        assert rows == _vertex_traffic_oracle(eng, state)
        # bytes are rows x the static payload size (PageRank f32 wire:
        # rank + contrib = 8 bytes), so under-/over-counted rows would
        # show up here too
        assert int(np.asarray(state.traffic_bytes_v).sum()) == 8 * rows

    def test_marker_wave_stand_down_keeps_count_exact(self):
        """Overlap stands down while a snapshot is in flight (§3.10) —
        those phases ship in-phase and must still be counted exactly
        once, and the wave's marker rows must not leak into traffic_v."""
        from repro.dist.engine import DistributedEngine
        prog, g = _pagerank(80, 3)
        eng = DistributedEngine(prog, g, _mesh(4), tolerance=1e-8,
                                method="bfs", overlap=True)
        state = eng.init()
        for _ in range(3):
            state = eng.step(state)
        state = eng.start_snapshot(state, (0,))
        while not eng.snapshot_complete(state):
            state = eng.step(state)
        assert eng.snapshot_violations(state) == 0
        state = eng.clear_snapshot(state)
        state, _ = eng.run(state, max_steps=600)
        assert float(jnp.max(state.prio)) <= 1e-8
        rows = int(np.asarray(state.traffic_v).sum())
        assert rows == _vertex_traffic_oracle(eng, state)
        assert int(np.asarray(state.traffic_bytes_v).sum()) == 8 * rows
